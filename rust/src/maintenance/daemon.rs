//! `drs maintain` — the long-running maintenance scheduler.
//!
//! The scrub/repair primitives ([`super::scrub`], [`super::repair`]) fix a
//! cluster when an operator runs them; this module runs them *unattended*,
//! the Ceph-style background maintenance loop the paper's small-VO pitch
//! needs: placements stay repairable without anyone babysitting `drs
//! scrub` / `drs repair-all` by hand. One daemon tick:
//!
//! 1. **Shallow incremental scrub** of the next [`DaemonOptions::scrub_slice`]
//!    EC directories after the persisted cursor (`scrub_cursor.json`, the
//!    same file `drs scrub --incremental` uses, so the daemon and manual
//!    runs share one resume point).
//! 2. **Deep scrub cadence**: once every [`DaemonOptions::deep_every`]
//!    full namespace passes, the whole pass runs in deep (checksum) mode,
//!    catching bit-rot that existence probes cannot see.
//! 3. **Budgeted repair** of whatever the slice found, most-urgent first,
//!    under the tick's [`RepairBudget`] — clients keep their bandwidth.
//! 4. **Journal housekeeping**: a bounded GC of sealed journal segments
//!    each tick, and a full checkpoint+GC ([`crate::catalog::ShardedDfc::compact_journal`])
//!    when a namespace pass completes, so a daemon workspace never
//!    balloons. No-op for in-memory (journal-less) catalogues.
//!
//! Between ticks the daemon sleeps [`DaemonOptions::scrub_interval`],
//! rewrites `maintain_status.json` (crash-safely, via
//! [`crate::util::atomic_write`]) with the current phase, cursor,
//! last-pass health counts, repair outcomes and a `maintenance.*` metrics
//! snapshot, and checks for a stop request. Stop requests arrive three
//! ways — SIGINT/SIGTERM (hooked by [`StopToken::hook_signals`]), a
//! `maintain.stop` file in the workspace (written by `drs maintain
//! --stop`), or [`StopToken::request_stop`] from another thread — and all
//! of them let the in-flight scrub/repair pass finish before the daemon
//! writes a final status dump and exits.
//!
//! Counters and timers land under `maintenance.daemon.*` in
//! [`crate::metrics::global`]. With [`DaemonOptions::status_addr`] set
//! the same status payload is additionally served live over HTTP
//! ([`crate::obs::http::StatusServer`]: `GET /status`, `/metrics`,
//! `/traces/recent`), and every tick is bracketed by a `daemon-tick`
//! trace span.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::dfm::EcShim;
use crate::metrics;
use crate::obs::http::StatusServer;
use crate::util::json::Json;
use crate::Result;

use super::drain::DrainOptions;
use super::repair::{RepairBudget, RepairSummary};
use super::scrub::{ScrubOptions, ScrubReport};
use super::Maintainer;

/// File (inside the daemon's state directory) holding the incremental
/// scrub resume cursor, shared with `drs scrub --incremental`.
pub const SCRUB_CURSOR_FILE: &str = "scrub_cursor.json";
/// File the daemon rewrites every tick with its current status.
pub const STATUS_FILE: &str = "maintain_status.json";
/// Touching this file in the state directory asks a running daemon to
/// stop after its in-flight pass (`drs maintain --stop` writes it).
pub const STOP_FILE: &str = "maintain.stop";

/// The daemon's status-file path inside `dir`.
pub fn status_path(dir: &Path) -> PathBuf {
    dir.join(STATUS_FILE)
}

/// The daemon's stop-file path inside `dir`.
pub fn stop_file_path(dir: &Path) -> PathBuf {
    dir.join(STOP_FILE)
}

/// Load the incremental-scrub cursor persisted in `dir` *for the same
/// scrub root*: the last EC directory examined, or `None` when the
/// previous walk completed, no cursor has been saved yet, or the saved
/// cursor belongs to a different root (a cursor from `/vo/b` must not
/// filter a walk of `/vo/a`).
pub fn load_scrub_cursor(dir: &Path, scrub_root: &str) -> Option<String> {
    let text = std::fs::read_to_string(dir.join(SCRUB_CURSOR_FILE)).ok()?;
    let j = Json::parse(&text).ok()?;
    if j.get("root")?.as_str()? != scrub_root {
        return None;
    }
    j.get("after")?.as_str().map(str::to_string)
}

/// Persist (or clear, with `None`) the incremental-scrub cursor in `dir`,
/// tagged with the scrub root it belongs to. Crash-safe.
pub fn save_scrub_cursor(dir: &Path, scrub_root: &str, cursor: Option<&str>) -> Result<()> {
    let j = match cursor {
        Some(c) => Json::obj(vec![("root", Json::str(scrub_root)), ("after", Json::str(c))]),
        None => Json::obj(vec![]),
    };
    crate::util::atomic_write(&dir.join(SCRUB_CURSOR_FILE), j.to_string().as_bytes())
}

/// Set by the process signal handler; checked by every [`StopToken`].
static SIGNAL_STOP: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod sig {
    use super::SIGNAL_STOP;
    use std::sync::atomic::Ordering;

    extern "C" fn on_signal(_signum: i32) {
        // Async-signal-safe: a single atomic store, nothing else.
        SIGNAL_STOP.store(true, Ordering::SeqCst);
    }

    // The libc crate is unavailable offline; std already links the C
    // library, so declare the one symbol we need directly.
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    pub(super) fn install() {
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        // SAFETY: `signal(2)` is in every libc std already links; the
        // handler only performs a single async-signal-safe atomic store.
        unsafe {
            signal(SIGINT, on_signal);
            signal(SIGTERM, on_signal);
        }
    }
}

/// Cooperative shutdown handle for a daemon run: carries an in-process
/// stop flag, optionally watches a stop file, and can hook the process
/// SIGINT/SIGTERM handlers. Clones share the same flag.
#[derive(Clone, Debug, Default)]
pub struct StopToken {
    requested: Arc<AtomicBool>,
    /// Whether this token (or a clone) opted into the process-global
    /// signal flag — a token that never hooked signals must not be
    /// stopped by a signal an earlier daemon run in the same process
    /// received.
    signals_hooked: Arc<AtomicBool>,
    stop_file: Option<PathBuf>,
}

impl StopToken {
    /// A token stoppable only via [`StopToken::request_stop`] (tests,
    /// embedded daemons).
    pub fn new() -> Self {
        Self::default()
    }

    /// A token that additionally treats the existence of `path` as a stop
    /// request. The daemon removes the file on clean exit so the next run
    /// starts fresh.
    pub fn with_stop_file(path: impl Into<PathBuf>) -> Self {
        StopToken { stop_file: Some(path.into()), ..Self::default() }
    }

    /// Ask the daemon to stop after its in-flight pass.
    pub fn request_stop(&self) {
        self.requested.store(true, Ordering::SeqCst);
    }

    /// Route the process's SIGINT/SIGTERM to a stop request (no-op on
    /// non-unix targets). The handler only flips an atomic, so the
    /// in-flight pass still completes before the daemon exits. Clears any
    /// signal left over from a previous hooked run in this process — each
    /// hook starts a fresh signal session.
    pub fn hook_signals(&self) {
        SIGNAL_STOP.store(false, Ordering::SeqCst);
        self.signals_hooked.store(true, Ordering::SeqCst);
        #[cfg(unix)]
        sig::install();
    }

    /// Why the daemon should stop, if it should: `"signal"`,
    /// `"stop-request"` or `"stop-file"`. `None` means keep running.
    pub fn cause(&self) -> Option<&'static str> {
        if self.signals_hooked.load(Ordering::SeqCst) && SIGNAL_STOP.load(Ordering::SeqCst) {
            return Some("signal");
        }
        if self.requested.load(Ordering::SeqCst) {
            return Some("stop-request");
        }
        if self.stop_file.as_ref().is_some_and(|p| p.exists()) {
            return Some("stop-file");
        }
        None
    }

    /// Whether a stop has been requested by any channel.
    pub fn should_stop(&self) -> bool {
        self.cause().is_some()
    }

    /// Remove the stop file (clean-exit housekeeping).
    fn consume_stop_file(&self) {
        if let Some(p) = &self.stop_file {
            let _ = std::fs::remove_file(p);
        }
    }
}

/// Cadences and budgets for one daemon run. All knobs have `drs.json` /
/// `DRS_MAINTAIN_*` counterparts (see [`crate::config::Config`]).
#[derive(Clone, Debug)]
pub struct DaemonOptions {
    /// Catalogue subtree the daemon maintains (`"/"` = everything).
    pub root: String,
    /// Sleep between ticks (`maintain_scrub_interval_s`). Zero means
    /// back-to-back ticks (tests).
    pub scrub_interval: Duration,
    /// EC directories scrubbed per tick (`maintain_scrub_slice`); 0 means
    /// the whole subtree every tick.
    pub scrub_slice: usize,
    /// Every `deep_every`-th full namespace pass runs in deep (checksum)
    /// mode (`maintain_deep_every`); 0 disables deep passes, 1 makes
    /// every pass deep.
    pub deep_every: u64,
    /// Per-tick repair budget (`maintain_repair_budget_*`).
    pub budget: RepairBudget,
    /// Scrub probe worker threads.
    pub workers: usize,
    /// Stop after this many ticks (`--ticks`); `None` runs until a stop
    /// request arrives.
    pub max_ticks: Option<u64>,
    /// Journal-GC byte budget per housekeeping tick.
    pub gc_budget: u64,
    /// When set, the daemon serves its live status over HTTP on this
    /// address (`GET /status`, `/metrics`, `/traces/recent` — see
    /// [`crate::obs::http::StatusServer`]) for the lifetime of the run
    /// (`drs maintain --status-addr`, `obs_status_addr` in `drs.json`).
    pub status_addr: Option<String>,
    /// Auto-drain an SE observed dark for this many *consecutive*
    /// completed namespace passes (`maintain_drain_after_passes`);
    /// 0 disables auto-drain. A pass where the SE is back up resets its
    /// streak; a failed drain attempt is retried at the next completed
    /// pass while the SE stays dark.
    pub drain_after_passes: u64,
}

impl Default for DaemonOptions {
    fn default() -> Self {
        DaemonOptions {
            root: "/".into(),
            scrub_interval: Duration::from_secs(30),
            scrub_slice: 64,
            deep_every: 4,
            budget: RepairBudget::default(),
            workers: 4,
            max_ticks: None,
            gc_budget: 4 << 20,
            status_addr: None,
            drain_after_passes: 0,
        }
    }
}

impl DaemonOptions {
    /// Scope the daemon to a catalogue subtree.
    pub fn with_root(mut self, root: impl Into<String>) -> Self {
        self.root = root.into();
        self
    }

    /// Set the inter-tick sleep.
    pub fn with_interval(mut self, interval: Duration) -> Self {
        self.scrub_interval = interval;
        self
    }

    /// Set the EC-directories-per-tick slice (0 = whole subtree).
    pub fn with_slice(mut self, slice: usize) -> Self {
        self.scrub_slice = slice;
        self
    }

    /// Set the deep-scrub cadence in full passes (0 = never deep).
    pub fn with_deep_every(mut self, deep_every: u64) -> Self {
        self.deep_every = deep_every;
        self
    }

    /// Set the per-tick repair budget.
    pub fn with_budget(mut self, budget: RepairBudget) -> Self {
        self.budget = budget;
        self
    }

    /// Set the scrub probe worker count (clamped to ≥ 1).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Bound the run to `ticks` ticks (`None` = run until stopped).
    pub fn with_max_ticks(mut self, ticks: Option<u64>) -> Self {
        self.max_ticks = ticks;
        self
    }

    /// Serve the daemon's live status over HTTP on `addr` for the
    /// lifetime of the run (`None` = no endpoint).
    pub fn with_status_addr(mut self, addr: Option<String>) -> Self {
        self.status_addr = addr;
        self
    }

    /// Auto-drain SEs dark for `passes` consecutive completed passes
    /// (0 = never).
    pub fn with_drain_after_passes(mut self, passes: u64) -> Self {
        self.drain_after_passes = passes;
        self
    }
}

/// Health counts of one completed namespace pass (pre-repair, summed over
/// its incremental slices).
#[derive(Clone, Copy, Debug, Default)]
pub struct PassHealth {
    /// EC files examined in the pass.
    pub files: usize,
    /// Files with every chunk fetchable when scrubbed.
    pub healthy: usize,
    /// Files found degraded (queued for repair).
    pub degraded: usize,
    /// Files found unrecoverable.
    pub lost: usize,
    /// Whether the pass ran in deep (checksum) mode.
    pub deep: bool,
}

/// Aggregate outcome of one daemon run.
#[derive(Clone, Debug, Default)]
pub struct DaemonReport {
    /// Scheduler ticks executed.
    pub ticks: u64,
    /// Full namespace passes completed.
    pub passes: u64,
    /// How many of those ran in deep (checksum) mode.
    pub deep_passes: u64,
    /// EC files scrubbed across all ticks (files in completed+partial passes).
    pub files_scrubbed: usize,
    /// Files successfully repaired.
    pub files_repaired: usize,
    /// Chunks re-derived by those repairs.
    pub chunks_rebuilt: usize,
    /// File repairs that failed (will be retried next pass).
    pub repair_failures: usize,
    /// Corrupt replicas whose quarantine failed (retried next deep pass).
    pub quarantine_failed: usize,
    /// Scrub slices that errored (daemon continued).
    pub scrub_errors: usize,
    /// SEs auto-drained after [`DaemonOptions::drain_after_passes`]
    /// consecutive dark passes, in drain order.
    pub auto_drained: Vec<String>,
    /// Auto-drain attempts that errored (retried next completed pass
    /// while the SE stays dark).
    pub auto_drain_failures: u64,
    /// Health counts of the most recently completed pass.
    pub last_pass: Option<PassHealth>,
    /// Why the run ended: `"tick-budget"`, `"signal"`, `"stop-request"`
    /// or `"stop-file"`.
    pub stopped_by: String,
}

impl DaemonReport {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} tick(s), {} pass(es) ({} deep): {} file(s) scrubbed, {} repaired \
             ({} chunks), {} repair failure(s), {} quarantine failure(s)",
            self.ticks,
            self.passes,
            self.deep_passes,
            self.files_scrubbed,
            self.files_repaired,
            self.chunks_rebuilt,
            self.repair_failures,
            self.quarantine_failed
        )
    }
}

/// Abort the run after this many *consecutive* failed scrub slices — a
/// persistently broken catalogue root should surface as an error, not an
/// infinite error loop.
const MAX_CONSECUTIVE_SCRUB_ERRORS: u32 = 10;

/// The `drs maintain` scheduler, bound to one shim and one state
/// directory (where the cursor, status and stop files live — the CLI
/// passes the workspace root).
pub struct Daemon<'a> {
    shim: &'a EcShim,
    opts: DaemonOptions,
    state_dir: PathBuf,
    /// The most recent status payload, shared with the embedded HTTP
    /// endpoint so `GET /status` never has to re-read (or race) the
    /// on-disk `maintain_status.json`.
    live_status: Arc<Mutex<Json>>,
    /// Address the status endpoint actually bound (`Some` only while a
    /// run with [`DaemonOptions::status_addr`] is in flight) — lets
    /// callers who asked for port 0 discover the ephemeral port.
    bound: Arc<Mutex<Option<std::net::SocketAddr>>>,
}

impl<'a> Daemon<'a> {
    /// Bind a daemon to a shim and a state directory.
    pub fn new(shim: &'a EcShim, opts: DaemonOptions, state_dir: impl Into<PathBuf>) -> Self {
        Daemon {
            shim,
            opts,
            state_dir: state_dir.into(),
            live_status: Arc::new(Mutex::new(Json::obj(vec![("phase", Json::str("starting"))]))),
            bound: Arc::new(Mutex::new(None)),
        }
    }

    /// The daemon's most recent status payload (what `GET /status`
    /// serves). Useful for embedding the daemon without the HTTP server.
    pub fn live_status(&self) -> Json {
        self.live_status.lock().unwrap().clone()
    }

    /// The address the live-status endpoint bound, while a run with
    /// [`DaemonOptions::status_addr`] is in flight (`None` otherwise).
    /// With `...:0` this is how the ephemeral port is discovered.
    pub fn status_endpoint(&self) -> Option<std::net::SocketAddr> {
        *self.bound.lock().unwrap()
    }

    /// Whether namespace pass `pass_no` (1-based) runs in deep mode.
    fn deep_pass(&self, pass_no: u64) -> bool {
        self.opts.deep_every > 0 && pass_no % self.opts.deep_every == 0
    }

    /// Run the scheduler until the tick budget is exhausted or `stop`
    /// fires. Every exit path — including the error one — writes a final
    /// status dump first. When [`DaemonOptions::status_addr`] is set the
    /// live-status HTTP endpoint is up for the whole run (a bind failure
    /// aborts the run before the first tick — an operator who asked for
    /// the endpoint should not silently run without it).
    pub fn run(&self, stop: &StopToken) -> Result<DaemonReport> {
        let server = match &self.opts.status_addr {
            Some(addr) => {
                let live = Arc::clone(&self.live_status);
                let status: crate::obs::http::StatusFn =
                    Arc::new(move || live.lock().unwrap().clone());
                let server = StatusServer::serve(addr, status)?;
                *self.bound.lock().unwrap() = Some(server.local_addr());
                Some(server)
            }
            None => None,
        };
        let res = self.run_loop(stop);
        if let Some(s) = server {
            s.stop();
            *self.bound.lock().unwrap() = None;
        }
        res
    }

    /// The scheduler proper (split out so [`Daemon::run`] can bracket it
    /// with the status endpoint's lifetime).
    fn run_loop(&self, stop: &StopToken) -> Result<DaemonReport> {
        let m = metrics::global();
        let mut report = DaemonReport::default();
        let mut cursor = load_scrub_cursor(&self.state_dir, &self.opts.root);
        let mut pass_no: u64 = 1;
        let mut pass = PassHealth { deep: self.deep_pass(1), ..Default::default() };
        let mut last_tick: Option<(ScrubReport, RepairSummary)> = None;
        let mut consecutive_errors: u32 = 0;
        // SE name → consecutive completed passes it has been dark.
        let mut dark_streaks: std::collections::BTreeMap<String, u64> =
            std::collections::BTreeMap::new();

        loop {
            if let Some(cause) = stop.cause() {
                report.stopped_by = cause.to_string();
                break;
            }
            if self.opts.max_ticks.is_some_and(|max| report.ticks >= max) {
                report.stopped_by = "tick-budget".to_string();
                break;
            }
            report.ticks += 1;
            m.inc("maintenance.daemon.ticks");

            // (a)/(b) One scrub slice: shallow on ordinary passes, deep
            // (checksum) once per deep_every full passes.
            let deep = self.deep_pass(pass_no);
            // Each tick is one trace: the scrub/repair roots it triggers
            // stay their own traces, but the tick span brackets the whole
            // unit of scheduled work for `drs trace summary`.
            let tick = report.ticks;
            let mut tick_span = crate::obs::tracer().span_with(
                crate::obs::SpanRef::NONE,
                "daemon-tick",
                || format!("tick {tick} pass {pass_no}{}", if deep { " deep" } else { "" }),
            );
            let mut sopts = ScrubOptions::default()
                .with_root(self.opts.root.clone())
                .with_workers(self.opts.workers);
            if !deep {
                sopts = sopts.shallow();
            }
            if self.opts.scrub_slice > 0 {
                sopts = sopts.with_max_dirs(self.opts.scrub_slice);
            }
            if let Some(c) = &cursor {
                sopts = sopts.resume_after(c.clone());
            }
            self.write_status(&report, "scrub", pass_no, deep, cursor.as_deref(), &last_tick);

            let maintainer = Maintainer::new(self.shim);
            let scrub = m.timed("maintenance.daemon.tick", || maintainer.scrub(&sopts));
            let scrub = match scrub {
                Ok(r) => {
                    consecutive_errors = 0;
                    r
                }
                Err(e) => {
                    // A transient scrub failure (e.g. an SE flapping
                    // mid-probe) must not kill an unattended daemon;
                    // a persistent one must not loop silently forever.
                    report.scrub_errors += 1;
                    m.inc("maintenance.daemon.scrub_errors");
                    consecutive_errors += 1;
                    tick_span.fail();
                    drop(tick_span);
                    if consecutive_errors >= MAX_CONSECUTIVE_SCRUB_ERRORS {
                        report.stopped_by = "scrub-errors".to_string();
                        self.finish(&report, pass_no, cursor.as_deref(), &last_tick, stop);
                        return Err(e);
                    }
                    self.sleep(stop);
                    continue;
                }
            };
            cursor = scrub.cursor.clone();
            if save_scrub_cursor(&self.state_dir, &self.opts.root, cursor.as_deref()).is_err() {
                // Cursor loss only costs a re-scan from the subtree start.
                m.inc("maintenance.daemon.cursor_errors");
            }

            // (c) Budgeted repair of whatever this slice found.
            self.write_status(&report, "repair", pass_no, deep, cursor.as_deref(), &last_tick);
            let summary = maintainer.repair_all(&scrub, &self.opts.budget);

            // (d) Journal housekeeping: cheap GC every tick, a full
            // checkpoint+GC when a namespace pass completes.
            let completed_pass = scrub.cursor.is_none();
            let dfc = self.shim.dfc();
            if dfc.is_journaled() {
                let gc = if completed_pass {
                    dfc.compact_journal(self.opts.gc_budget).map(|r| r.bytes_removed)
                } else {
                    dfc.journal_gc(self.opts.gc_budget).map(|(_, b)| b)
                };
                match gc {
                    Ok(bytes) => m.add("maintenance.daemon.gc_bytes", bytes),
                    Err(_) => m.inc("maintenance.daemon.journal_errors"),
                }
            }

            // Account the tick into the current pass and the run totals.
            pass.files += scrub.files.len();
            pass.healthy += scrub.healthy();
            pass.degraded += scrub.degraded();
            pass.lost += scrub.lost();
            report.files_scrubbed += scrub.files.len();
            report.files_repaired += summary.files_repaired();
            report.chunks_rebuilt += summary.chunks_rebuilt;
            report.repair_failures += summary.files_failed;
            report.quarantine_failed += summary.quarantine_failed;
            last_tick = Some((scrub, summary));
            if completed_pass {
                report.passes += 1;
                m.inc("maintenance.daemon.passes");
                if pass.deep {
                    report.deep_passes += 1;
                    m.inc("maintenance.daemon.deep_passes");
                }
                report.last_pass = Some(pass);
                pass_no += 1;
                pass = PassHealth { deep: self.deep_pass(pass_no), ..Default::default() };
                // (e) Auto-drain: an SE dark for `drain_after_passes`
                // consecutive completed passes is evacuated so its data
                // regains full redundancy elsewhere without an operator.
                if self.opts.drain_after_passes > 0 {
                    self.auto_drain(&mut dark_streaks, &mut report);
                }
            }

            // Close the tick's trace before the idle sleep — the span
            // should time the work, not the interval.
            drop(tick_span);

            // Recompute the deep flag for the idle dump: a completed pass
            // bumped pass_no, and `deep` must describe the *upcoming*
            // pass for whoever polls the status file during the sleep.
            let next_deep = self.deep_pass(pass_no);
            self.write_status(&report, "idle", pass_no, next_deep, cursor.as_deref(), &last_tick);
            self.sleep(stop);
        }

        self.finish(&report, pass_no, cursor.as_deref(), &last_tick, stop);
        Ok(report)
    }

    /// Update per-SE dark streaks at a completed-pass boundary and drain
    /// any SE whose streak reached the threshold. An SE observed up
    /// resets its streak; an SE already auto-drained this run is left
    /// alone (drain is idempotent but not free).
    fn auto_drain(
        &self,
        dark_streaks: &mut std::collections::BTreeMap<String, u64>,
        report: &mut DaemonReport,
    ) {
        let m = metrics::global();
        for se in self.shim.registry().all() {
            let name = se.name().to_string();
            if se.is_available() {
                dark_streaks.remove(&name);
                continue;
            }
            let streak = dark_streaks.entry(name.clone()).or_insert(0);
            *streak += 1;
            let due = *streak >= self.opts.drain_after_passes
                && !report.auto_drained.iter().any(|d| d == &name);
            if !due {
                continue;
            }
            let dopts = DrainOptions::default().with_workers(self.opts.workers);
            match Maintainer::new(self.shim).drain(&name, &dopts) {
                Ok(dr) => {
                    m.inc("maintenance.daemon.auto_drains");
                    crate::obs::tracer().event(
                        crate::obs::SpanRef::NONE,
                        "auto-drain",
                        dr.clean(),
                        || format!("dark {streak} pass(es): {}", dr.summary()),
                    );
                    report.auto_drained.push(name);
                }
                Err(e) => {
                    m.inc("maintenance.daemon.auto_drain_errors");
                    report.auto_drain_failures += 1;
                    crate::obs::tracer().event(
                        crate::obs::SpanRef::NONE,
                        "auto-drain",
                        false,
                        || format!("`{name}` dark {streak} pass(es): drain failed: {e}"),
                    );
                }
            }
        }
    }

    /// Final status dump + stop-file consumption, shared by every exit
    /// path.
    fn finish(
        &self,
        report: &DaemonReport,
        pass_no: u64,
        cursor: Option<&str>,
        last_tick: &Option<(ScrubReport, RepairSummary)>,
        stop: &StopToken,
    ) {
        self.write_status(report, "stopped", pass_no, self.deep_pass(pass_no), cursor, last_tick);
        stop.consume_stop_file();
    }

    /// Sleep the inter-tick interval in small increments so a stop
    /// request interrupts the wait promptly.
    fn sleep(&self, stop: &StopToken) {
        let mut remaining = self.opts.scrub_interval;
        let step = Duration::from_millis(25);
        while !remaining.is_zero() && !stop.should_stop() {
            let d = remaining.min(step);
            std::thread::sleep(d);
            remaining = remaining.saturating_sub(d);
        }
    }

    /// Rewrite `maintain_status.json` (best-effort; failures are counted,
    /// never fatal — the status file is observability, not state).
    fn write_status(
        &self,
        report: &DaemonReport,
        phase: &str,
        pass_no: u64,
        deep: bool,
        cursor: Option<&str>,
        last_tick: &Option<(ScrubReport, RepairSummary)>,
    ) {
        let m = metrics::global();
        let mut pairs = vec![
            ("phase", Json::str(phase)),
            ("root", Json::str(self.opts.root.clone())),
            ("tick", Json::num(report.ticks as f64)),
            ("pass", Json::num(pass_no as f64)),
            ("deep", Json::Bool(deep)),
            ("cursor", cursor.map_or(Json::Null, Json::str)),
            (
                "totals",
                Json::obj(vec![
                    ("ticks", Json::num(report.ticks as f64)),
                    ("passes", Json::num(report.passes as f64)),
                    ("deep_passes", Json::num(report.deep_passes as f64)),
                    ("files_scrubbed", Json::num(report.files_scrubbed as f64)),
                    ("files_repaired", Json::num(report.files_repaired as f64)),
                    ("chunks_rebuilt", Json::num(report.chunks_rebuilt as f64)),
                    ("repair_failures", Json::num(report.repair_failures as f64)),
                    ("quarantine_failed", Json::num(report.quarantine_failed as f64)),
                    ("scrub_errors", Json::num(report.scrub_errors as f64)),
                ]),
            ),
        ];
        if self.opts.drain_after_passes > 0 {
            pairs.push((
                "auto_drain",
                Json::obj(vec![
                    ("after_passes", Json::num(self.opts.drain_after_passes as f64)),
                    (
                        "drained",
                        Json::Arr(
                            report.auto_drained.iter().map(|s| Json::str(s.as_str())).collect(),
                        ),
                    ),
                    ("failures", Json::num(report.auto_drain_failures as f64)),
                ]),
            ));
        }
        if !report.stopped_by.is_empty() {
            pairs.push(("stopped_by", Json::str(report.stopped_by.clone())));
        }
        if let Some(p) = &report.last_pass {
            pairs.push((
                "last_pass",
                Json::obj(vec![
                    ("files", Json::num(p.files as f64)),
                    ("healthy", Json::num(p.healthy as f64)),
                    ("degraded", Json::num(p.degraded as f64)),
                    ("lost", Json::num(p.lost as f64)),
                    ("deep", Json::Bool(p.deep)),
                ]),
            ));
        }
        if let Some((scrub, repair)) = last_tick {
            pairs.push((
                "last_tick",
                Json::obj(vec![
                    ("files", Json::num(scrub.files.len() as f64)),
                    ("healthy", Json::num(scrub.healthy() as f64)),
                    ("degraded", Json::num(scrub.degraded() as f64)),
                    ("lost", Json::num(scrub.lost() as f64)),
                    ("chunks_probed", Json::num(scrub.chunks_probed as f64)),
                    ("chunks_missing", Json::num(scrub.chunks_missing as f64)),
                    ("chunks_corrupt", Json::num(scrub.chunks_corrupt as f64)),
                    ("repaired", Json::num(repair.files_repaired() as f64)),
                    ("chunks_rebuilt", Json::num(repair.chunks_rebuilt as f64)),
                    ("repair_failed", Json::num(repair.files_failed as f64)),
                    ("deferred", Json::num(repair.deferred.len() as f64)),
                    ("quarantined", Json::num(repair.quarantined as f64)),
                    ("quarantine_failed", Json::num(repair.quarantine_failed as f64)),
                ]),
            ));
        }
        let metrics_snap: Vec<(String, Json)> = m
            .counters_with_prefix("maintenance.")
            .into_iter()
            .map(|(k, v)| (k, Json::num(v as f64)))
            .collect();
        pairs.push(("metrics", Json::Obj(metrics_snap.into_iter().collect())));
        let payload = Json::obj(pairs);
        // Publish to the live endpoint first — even if the disk write
        // fails, `GET /status` keeps serving fresh state.
        *self.live_status.lock().unwrap() = payload.clone();
        let body = payload.to_string();
        if crate::util::atomic_write(&status_path(&self.state_dir), body.as_bytes()).is_err() {
            m.inc("maintenance.daemon.status_errors");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "drs-daemon-{tag}-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn cursor_roundtrip_and_root_binding() {
        let dir = tmp("cursor");
        assert_eq!(load_scrub_cursor(&dir, "/"), None);
        save_scrub_cursor(&dir, "/", Some("/vo/data/f9.ec")).unwrap();
        assert_eq!(load_scrub_cursor(&dir, "/"), Some("/vo/data/f9.ec".to_string()));
        // Bound to its root: a different root ignores it.
        assert_eq!(load_scrub_cursor(&dir, "/vo/other"), None);
        save_scrub_cursor(&dir, "/", None).unwrap();
        assert_eq!(load_scrub_cursor(&dir, "/"), None);
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn stop_token_channels() {
        let t = StopToken::new();
        assert!(!t.should_stop());
        let t2 = t.clone();
        t2.request_stop();
        assert_eq!(t.cause(), Some("stop-request"));

        let dir = tmp("stop");
        let path = stop_file_path(&dir);
        let f = StopToken::with_stop_file(&path);
        assert!(!f.should_stop());
        std::fs::write(&path, b"stop").unwrap();
        assert_eq!(f.cause(), Some("stop-file"));
        f.consume_stop_file();
        assert!(!f.should_stop());
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn auto_drain_fires_after_consecutive_dark_passes() {
        use crate::dfm::{PutOptions, TestCluster};
        use crate::ec::EcParams;

        let cluster = TestCluster::builder()
            .ses(5)
            .ec(EcParams::new(2, 1).unwrap())
            .build()
            .unwrap();
        let opts = PutOptions::default()
            .with_params(EcParams::new(2, 1).unwrap())
            .with_stripe(512);
        for i in 0..3 {
            let data: Vec<u8> = (0..4000 + i * 100).map(|b| (b * 13 % 251) as u8).collect();
            cluster.shim().put_bytes(&format!("/vo/data/f{i}.bin"), &data, &opts).unwrap();
        }
        let victim = cluster
            .dfc()
            .files_with_replica_on("SE-01")
            .first()
            .map(|_| "SE-01")
            .unwrap_or("SE-02");
        cluster.registry().get(victim).unwrap().set_available(false);

        let dir = tmp("autodrain");
        // Whole-namespace pass per tick, drain after 2 dark passes.
        let d = Daemon::new(
            cluster.shim(),
            DaemonOptions::default()
                .with_interval(Duration::ZERO)
                .with_slice(0)
                .with_max_ticks(Some(3))
                .with_drain_after_passes(2),
            &dir,
        );
        let report = d.run(&StopToken::new()).unwrap();
        assert_eq!(report.passes, 3);
        assert_eq!(report.auto_drained, vec![victim.to_string()], "{report:?}");
        assert_eq!(report.auto_drain_failures, 0);
        // Nothing catalogued points at the drained SE any more.
        assert_eq!(cluster.dfc().files_with_replica_on(victim).len(), 0);
        // The status dump carries the auto-drain section.
        let status = d.live_status();
        let drained = status.get("auto_drain").and_then(|j| j.get("drained")).unwrap();
        assert_eq!(drained.as_arr().map(|a| a.len()), Some(1));
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn auto_drain_streak_resets_when_se_returns() {
        use crate::dfm::TestCluster;
        use crate::ec::EcParams;

        let cluster = TestCluster::builder()
            .ses(4)
            .ec(EcParams::new(2, 1).unwrap())
            .build()
            .unwrap();
        let dir = tmp("autodrain-reset");
        let d = Daemon::new(
            cluster.shim(),
            DaemonOptions::default()
                .with_interval(Duration::ZERO)
                .with_slice(0)
                .with_max_ticks(Some(3))
                .with_drain_after_passes(2),
            &dir,
        );
        // Every SE stays up: nothing may drain.
        let report = d.run(&StopToken::new()).unwrap();
        assert!(report.auto_drained.is_empty());
        assert_eq!(report.auto_drain_failures, 0);
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn deep_cadence() {
        let cluster = crate::dfm::TestCluster::builder()
            .ses(4)
            .ec(crate::ec::EcParams::new(2, 1).unwrap())
            .build()
            .unwrap();
        let mk = |every| {
            Daemon::new(
                cluster.shim(),
                DaemonOptions::default().with_deep_every(every),
                std::env::temp_dir(),
            )
        };
        let d = mk(4);
        assert!(!d.deep_pass(1) && !d.deep_pass(3));
        assert!(d.deep_pass(4) && d.deep_pass(8));
        let every = mk(1);
        assert!(every.deep_pass(1) && every.deep_pass(2));
        let never = mk(0);
        assert!(!never.deep_pass(1) && !never.deep_pass(100));
    }
}
