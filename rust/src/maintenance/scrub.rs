//! The scrubber: catalogue-wide health assessment of erasure-coded files.
//!
//! A scrub walks every EC directory under a root (found through the DFC
//! iteration helpers by their `TOTAL`/`SPLIT` metadata, either key style),
//! probes each chunk replica's SE for existence — and, in deep mode, for a
//! checksum match against the catalogue record — and folds the results
//! into one [`FileHealth`] per file. The probe phase runs through the
//! §2.4 work pool, one job per file.
//!
//! The walk itself runs against a lock-free point-in-time snapshot
//! ([`crate::catalog::ShardedDfc::snapshot_subtree`]), so a full
//! catalogue scrub never blocks client operations. Incremental mode
//! (`max_dirs` + `resume_after`) bounds one run to a slice of the
//! namespace and reports a cursor ([`ScrubReport::cursor`]) to resume
//! from, which is what a maintenance daemon persists between runs.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::catalog::{dfc::DirItem, Dfc, MetaKeyStyle, Replica, ShardedDfc};
use crate::obs::{tracer, SpanRef};
use crate::se::SeRegistry;
use crate::transfer::{PoolConfig, WorkPool};
use crate::{Error, Result};

/// Block size for streamed deep-scrub checksumming (1 MiB: large enough
/// to amortize per-read overhead, small enough that N probe workers stay
/// cheap).
const SCRUB_HASH_BLOCK: usize = 1 << 20;

/// Scrub parameters.
#[derive(Clone, Debug)]
pub struct ScrubOptions {
    /// Catalogue subtree to scrub (`"/"` = everything).
    pub root: String,
    /// Deep scrub: fetch every surviving replica and verify its SHA-256
    /// against the catalogue checksum. Shallow scrubs only probe
    /// existence + SE availability.
    pub verify_checksums: bool,
    /// Probe worker threads (one job per file).
    pub workers: usize,
    /// Incremental mode: scrub at most this many EC directories per run
    /// (in sorted LFN order), reporting where the run stopped in
    /// [`ScrubReport::cursor`]. `None` scrubs the whole subtree.
    pub max_dirs: Option<usize>,
    /// Incremental mode: skip EC directories up to and including this
    /// LFN (a [`ScrubReport::cursor`] from the previous run).
    pub resume_after: Option<String>,
}

impl Default for ScrubOptions {
    fn default() -> Self {
        ScrubOptions {
            root: "/".into(),
            verify_checksums: true,
            workers: 4,
            max_dirs: None,
            resume_after: None,
        }
    }
}

impl ScrubOptions {
    /// Scope the scrub to a catalogue subtree.
    pub fn with_root(mut self, root: impl Into<String>) -> Self {
        self.root = root.into();
        self
    }

    /// Set the probe worker count (clamped to ≥ 1).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Skip checksum verification (existence/availability probes only).
    pub fn shallow(mut self) -> Self {
        self.verify_checksums = false;
        self
    }

    /// Incremental mode: bound one run to `max_dirs` EC directories
    /// (clamped to ≥ 1).
    pub fn with_max_dirs(mut self, max_dirs: usize) -> Self {
        self.max_dirs = Some(max_dirs.max(1));
        self
    }

    /// Incremental mode: resume after the given cursor (the last LFN the
    /// previous run examined).
    pub fn resume_after(mut self, cursor: impl Into<String>) -> Self {
        self.resume_after = Some(cursor.into());
        self
    }
}

/// Health classification of one EC file.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HealthState {
    /// All N chunks fetchable.
    Healthy,
    /// Some chunks lost but ≥ K survive — repairable.
    Degraded,
    /// Fewer than K chunks survive — unrecoverable by repair.
    Lost,
}

/// A replica whose bytes exist but fail the catalogue checksum.
#[derive(Clone, Debug)]
pub struct CorruptReplica {
    /// Chunk index within the code word.
    pub index: usize,
    /// Catalogue path of the chunk file (for record removal).
    pub path: String,
    /// SE holding the corrupt copy.
    pub se: String,
    /// Physical file name of the corrupt copy.
    pub pfn: String,
}

/// Per-file scrub verdict.
#[derive(Clone, Debug)]
pub struct FileHealth {
    /// The EC file's logical path (its chunk directory).
    pub lfn: String,
    /// Data chunks needed to reconstruct (the catalogue `SPLIT`).
    pub k: usize,
    /// Total chunks (the catalogue `TOTAL`).
    pub n: usize,
    /// Chunks with at least one good replica.
    pub available: usize,
    /// Chunk indices with no live replica at all.
    pub missing: Vec<usize>,
    /// Replicas present but checksum-bad (deep scrub only; one entry per
    /// bad replica, including bad copies of chunks that remain available
    /// through a good replica). A chunk with only corrupt replicas is
    /// counted unavailable.
    pub corrupt: Vec<CorruptReplica>,
    /// Estimated bytes a repair must rebuild (sum of lost chunk sizes).
    pub repair_bytes: u64,
}

impl FileHealth {
    /// Classify the file from its surviving chunk count.
    pub fn state(&self) -> HealthState {
        if self.available == self.n {
            HealthState::Healthy
        } else if self.available >= self.k {
            HealthState::Degraded
        } else {
            HealthState::Lost
        }
    }

    /// Surviving margin: chunks that can still be lost before the file
    /// is. Negative once the file is already unreadable.
    pub fn margin(&self) -> isize {
        self.available as isize - self.k as isize
    }

    /// The margin of a fully healthy file (N − K).
    pub fn full_margin(&self) -> usize {
        self.n - self.k
    }

    /// Whether any chunk needs rebuilding.
    pub fn needs_repair(&self) -> bool {
        self.available < self.n
    }
}

/// Aggregate scrub outcome.
#[derive(Clone, Debug, Default)]
pub struct ScrubReport {
    /// One entry per EC file, in catalogue order.
    pub files: Vec<FileHealth>,
    /// EC-tagged directories that could not be parsed (missing/garbled
    /// metadata, no chunk files) — surfaced rather than silently skipped.
    pub skipped: Vec<(String, String)>,
    /// Total chunks examined (N per file).
    pub chunks_probed: usize,
    /// Chunks with no live replica at all.
    pub chunks_missing: usize,
    /// Chunks with at least one checksum-bad replica (deep scrub).
    pub chunks_corrupt: usize,
    /// Incremental mode: the last EC directory this run examined, when
    /// the `max_dirs` budget stopped the walk early. `None` means the
    /// subtree walk completed — the next incremental run starts over.
    pub cursor: Option<String>,
}

impl ScrubReport {
    /// Files with every chunk fetchable.
    pub fn healthy(&self) -> usize {
        self.count(HealthState::Healthy)
    }

    /// Files with lost chunks but still ≥ K survivors.
    pub fn degraded(&self) -> usize {
        self.count(HealthState::Degraded)
    }

    /// Files with fewer than K surviving chunks.
    pub fn lost(&self) -> usize {
        self.count(HealthState::Lost)
    }

    fn count(&self, state: HealthState) -> usize {
        self.files.iter().filter(|f| f.state() == state).count()
    }

    /// Repairable files ordered most-urgent first: smallest surviving
    /// margin, ties broken by LFN for determinism. Lost files are not in
    /// the queue (repair cannot help them); fully healthy files neither.
    pub fn repair_queue(&self) -> Vec<&FileHealth> {
        let mut q: Vec<&FileHealth> = self
            .files
            .iter()
            .filter(|f| f.state() == HealthState::Degraded)
            .collect();
        q.sort_by(|a, b| a.margin().cmp(&b.margin()).then_with(|| a.lfn.cmp(&b.lfn)));
        q
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} files: {} healthy, {} degraded, {} lost ({} chunks probed, {} missing, {} corrupt)",
            self.files.len(),
            self.healthy(),
            self.degraded(),
            self.lost(),
            self.chunks_probed,
            self.chunks_missing,
            self.chunks_corrupt
        )
    }
}

/// Catalogue snapshot of one EC file, taken under the DFC lock so the
/// probe phase runs lock-free.
struct FileLayout {
    lfn: String,
    k: usize,
    n: usize,
    chunks: Vec<ChunkRecord>,
}

struct ChunkRecord {
    index: usize,
    /// Catalogue path of the chunk file.
    path: String,
    checksum: String,
    size: u64,
    replicas: Vec<Replica>,
}

/// Whether a metadata map carries the EC TOTAL+SPLIT tags, under either
/// the paper's generic (V1) or the prefixed (V2) key style.
pub(crate) fn is_ec_meta(meta: &crate::catalog::meta::MetaMap) -> bool {
    [MetaKeyStyle::V2Prefixed, MetaKeyStyle::V1Generic]
        .iter()
        .any(|s| meta.contains_key(s.total_key()) && meta.contains_key(s.split_key()))
}

/// Whether `path` names an EC file directory (single metadata lookup; no
/// subtree walk).
pub fn is_ec_dir(dfc: &Dfc, path: &str) -> bool {
    dfc.is_dir(path) && dfc.meta(path).map(is_ec_meta).unwrap_or(false)
}

/// [`is_ec_dir`] against the live sharded catalogue (one owner-shard
/// metadata lookup).
pub fn is_ec_dir_sharded(dfc: &ShardedDfc, path: &str) -> bool {
    dfc.is_dir(path) && dfc.meta(path).map(|m| is_ec_meta(&m)).unwrap_or(false)
}

/// Find every EC file directory under `root`.
pub fn find_ec_dirs(dfc: &Dfc, root: &str) -> Result<Vec<String>> {
    dfc.dirs_where(root, |_, meta| is_ec_meta(meta))
}

fn meta_int(dfc: &Dfc, lfn: &str, key_v2: &str, key_v1: &str) -> Option<i64> {
    dfc.get_meta(lfn, key_v2)
        .ok()
        .flatten()
        .or_else(|| dfc.get_meta(lfn, key_v1).ok().flatten())
        .and_then(|v| v.as_int())
}

fn snapshot(dfc: &Dfc, lfn: &str) -> Result<FileLayout> {
    let v2 = MetaKeyStyle::V2Prefixed;
    let v1 = MetaKeyStyle::V1Generic;
    let total = meta_int(dfc, lfn, v2.total_key(), v1.total_key())
        .ok_or_else(|| Error::Catalog(format!("`{lfn}`: missing TOTAL metadata")))?;
    let split = meta_int(dfc, lfn, v2.split_key(), v1.split_key())
        .ok_or_else(|| Error::Catalog(format!("`{lfn}`: missing SPLIT metadata")))?;
    let (n, k) = (total as usize, split as usize);
    if k == 0 || k > n {
        return Err(Error::Catalog(format!("`{lfn}`: bad geometry k={k} n={n}")));
    }

    let mut chunks = Vec::new();
    for item in dfc.list_dir(lfn)? {
        if let DirItem::File(name) = &item {
            if let Some((_base, index, n_from_name)) = crate::ec::parse_chunk_name(name) {
                if n_from_name != n {
                    return Err(Error::Catalog(format!(
                        "`{lfn}`: chunk `{name}` claims n={n_from_name}, metadata says {n}"
                    )));
                }
                let path = format!("{lfn}/{name}");
                let entry = dfc.file(&path)?;
                chunks.push(ChunkRecord {
                    index,
                    path,
                    checksum: entry.checksum.clone(),
                    size: entry.size,
                    replicas: entry.replicas.clone(),
                });
            }
        }
    }
    if chunks.is_empty() {
        return Err(Error::Catalog(format!("`{lfn}` holds no chunk files")));
    }
    chunks.sort_by_key(|c| c.index);
    Ok(FileLayout { lfn: lfn.to_string(), k, n, chunks })
}

/// Probe one file's chunks against the registry. Pure function of the
/// snapshot + live SE state; no catalogue access.
fn probe(layout: &FileLayout, registry: &SeRegistry, verify: bool) -> FileHealth {
    let mut missing = Vec::new();
    let mut corrupt = Vec::new();
    let mut available = 0usize;
    let mut repair_bytes = 0u64;

    for chunk in &layout.chunks {
        let mut ok = false;
        // Deep mode probes *every* replica — no early break on the first
        // good copy — and records each checksum-bad one, so the repair
        // pass can quarantine a corrupt copy sitting beside a good one.
        let mut bad_replicas: Vec<CorruptReplica> = Vec::new();
        for r in &chunk.replicas {
            let Some(se) = registry.get(&r.se) else { continue };
            if !se.is_available() || !se.exists(&r.pfn) {
                continue;
            }
            if verify && !chunk.checksum.is_empty() {
                // Deep mode streams the object through the incremental
                // hasher block-by-block (`se::hash_object`): a deep scrub
                // of terabyte-scale chunks holds one block, not a chunk.
                match crate::se::hash_object(se.as_ref(), &r.pfn, SCRUB_HASH_BLOCK) {
                    Ok(digest) => {
                        let got = crate::util::hexfmt::encode(&digest);
                        if got == chunk.checksum {
                            ok = true;
                        } else {
                            bad_replicas.push(CorruptReplica {
                                index: chunk.index,
                                path: chunk.path.clone(),
                                se: r.se.clone(),
                                pfn: r.pfn.clone(),
                            });
                        }
                    }
                    Err(_) => continue,
                }
            } else {
                ok = true;
                break;
            }
        }
        if ok {
            available += 1;
        } else {
            repair_bytes += chunk.size;
            if bad_replicas.is_empty() {
                missing.push(chunk.index);
            }
        }
        corrupt.extend(bad_replicas);
    }

    FileHealth {
        lfn: layout.lfn.clone(),
        k: layout.k,
        n: layout.n,
        available,
        missing,
        corrupt,
        repair_bytes,
    }
}

/// Run a scrub over the catalogue. The run is traced as a `scrub` root
/// span with one `scrub-slice` child per file probed (a slice span is
/// marked failed when the file turns out unrecoverable).
pub fn scrub(
    dfc: &ShardedDfc,
    registry: &Arc<SeRegistry>,
    opts: &ScrubOptions,
) -> Result<ScrubReport> {
    let root = tracer().span_with(SpanRef::NONE, "scrub", || opts.root.clone());
    let parent = root.handle();
    root.finish(scrub_steps(dfc, registry, opts, parent))
}

fn scrub_steps(
    dfc: &ShardedDfc,
    registry: &Arc<SeRegistry>,
    opts: &ScrubOptions,
    parent: SpanRef,
) -> Result<ScrubReport> {
    // Snapshot phase: clone the subtree out of each catalogue shard
    // (each shard's lock held only for its own clone), then walk the
    // snapshot with no locks at all — client operations are never
    // blocked for the duration of the walk.
    let snap = dfc.snapshot_subtree(&opts.root)?;
    let mut dirs = find_ec_dirs(&snap, &opts.root)?;
    // Sorted order makes the incremental cursor well-defined across runs
    // (the walk's DFS order is not globally lexicographic).
    dirs.sort();
    if let Some(after) = &opts.resume_after {
        dirs.retain(|d| d.as_str() > after.as_str());
    }
    let mut cursor = None;
    if let Some(max) = opts.max_dirs {
        let max = max.max(1);
        if dirs.len() > max {
            dirs.truncate(max);
            cursor = dirs.last().cloned();
        }
    }
    let (layouts, skipped) = {
        let mut layouts = Vec::new();
        let mut skipped = Vec::new();
        for lfn in dirs {
            match snapshot(&snap, &lfn) {
                Ok(l) => layouts.push(l),
                Err(e) => skipped.push((lfn, e.to_string())),
            }
        }
        (layouts, skipped)
    };

    // Probe phase: one pool job per file. The closures borrow `layouts`;
    // the pool's scoped threads make that sound without boxing.
    let verify = opts.verify_checksums;
    let jobs: Vec<(usize, _)> = layouts
        .iter()
        .enumerate()
        .map(|(i, layout)| {
            let registry = Arc::clone(registry);
            (i, move || {
                let mut sp =
                    tracer().span_with(parent, "scrub-slice", || layout.lfn.clone());
                let health = probe(layout, &registry, verify);
                if health.state() == HealthState::Lost {
                    sp.fail();
                }
                drop(sp);
                Ok((i, health))
            })
        })
        .collect();
    let outcome = WorkPool::new(PoolConfig::parallel(opts.workers)).run(jobs, usize::MAX);

    let mut by_index: BTreeMap<usize, FileHealth> = outcome
        .successes
        .into_iter()
        .map(|(_, (i, h))| (i, h))
        .collect();
    let files: Vec<FileHealth> = (0..layouts.len()).filter_map(|i| by_index.remove(&i)).collect();

    let mut report = ScrubReport { files, skipped, cursor, ..Default::default() };
    for f in &report.files {
        report.chunks_probed += f.n;
        report.chunks_missing += f.missing.len();
        // `corrupt` is replica-level (a chunk can have several bad
        // replicas); count chunks, not replicas.
        let distinct: std::collections::BTreeSet<usize> =
            f.corrupt.iter().map(|c| c.index).collect();
        report.chunks_corrupt += distinct.len();
    }
    Ok(report)
}
