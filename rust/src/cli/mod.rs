//! The `drs` command-line interface.
//!
//! A workspace directory (default `./drs-workspace`, or `--workspace DIR`)
//! holds the catalogue's per-shard write-ahead journal (`journal/`), the
//! config (`drs.json`) and one subdirectory per (directory-backed) SE.
//! Commands mirror the paper's tool plus the further-work features:
//!
//! ```text
//! drs init [--ses N]                create a workspace
//! drs put <local-file> <lfn>        erasure-coded upload
//! drs get <lfn> <local-file>        reconstruct + download
//! drs ls <path>                     list catalog namespace
//! drs stat <lfn>                    chunk health report
//! drs repair <lfn>                  re-derive lost chunks
//! drs scrub [--root P] [--shallow] [--incremental N]
//!                                   catalogue-wide chunk health report
//!                                   (incremental: resume-cursor slices)
//! drs repair-all [--max-files N]    prioritized repair of degraded files
//! drs drain <se-name>               evacuate all chunks off an SE
//! drs maintain [--ticks N] [--stop] unattended scrub/repair daemon
//!                                   (incremental slices, deep cadence,
//!                                   budgeted repairs, status file)
//! drs rm <lfn>                      delete file + chunks
//! drs catalog compact|stats         journal checkpoint/GC + health report
//! drs se list|kill|revive           SE management / failure injection
//! drs durability [--p 0.9]          the §1.1 comparison table
//! drs meta <lfn>                    show catalog metadata
//! drs info                          artifact + backend report
//! ```

pub mod args;
pub mod commands;
pub mod workspace;

pub use args::{parse_args, Cli, Command};
pub use workspace::Workspace;

/// CLI entrypoint; returns the process exit code.
pub fn run(argv: Vec<String>) -> i32 {
    let cli = match parse_args(argv) {
        Ok(cli) => cli,
        Err(msg) => {
            eprintln!("error: {msg}\n");
            eprintln!("{}", args::USAGE);
            return 2;
        }
    };
    match commands::dispatch(&cli) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}
