//! Hand-rolled argument parsing (clap is unavailable offline).

/// The `drs help` text.
pub const USAGE: &str = "\
drs — erasure-coded DIRAC-style file management (CHEP2015 reproduction)

USAGE:
    drs [--workspace DIR] <COMMAND> [ARGS]

COMMANDS:
    init [--ses N] [--k K] [--m M] [--vo VO]   create a workspace
    put <local-file> <lfn> [--workers W] [--k K] [--m M] [--retry] [--stats]
    get <lfn> <local-file> [--workers W] [--retry] [--stats]
    ls [path]
    stat <lfn>
    repair <lfn> [--workers W]
    scrub [--root PATH] [--workers W] [--shallow] [--incremental N]
                                               probe every EC file's chunks
                                               (deep scrub checksums them);
                                               --incremental N scrubs the next
                                               N files after the saved cursor
    repair-all [--root PATH] [--workers W] [--max-files N] [--max-mb MB] [--shallow]
                                               scrub, then repair degraded
                                               files, smallest margin first
    drain <se-name> [--workers W]              evacuate all chunks off an SE
    serve <se-name> [--addr HOST:PORT]         expose the named SE's chunk
                                               store over TCP (default
                                               127.0.0.1:7070) for remote
                                               workspaces whose config lists
                                               this address as the SE's
                                               `endpoint`; blocks until killed
    maintain [--root PATH] [--interval-s S] [--slice N] [--deep-every N]
             [--max-files N] [--max-mb MB] [--workers W] [--ticks N]
             [--status-addr HOST:PORT] [--drain-after N]
                                               long-running maintenance daemon:
                                               incremental scrub + budgeted
                                               repair + journal GC on a cadence;
                                               writes maintain_status.json;
                                               --status-addr serves it live over
                                               HTTP (also /metrics, /traces/recent);
                                               SIGINT/SIGTERM (or --ticks) ends
                                               the run after the in-flight pass;
                                               --drain-after N auto-drains an SE
                                               dark for N consecutive passes
    maintain --stop                            ask a running daemon to stop
                                               cleanly (writes maintain.stop)
    trace tail [--n N]                         last N spans from the workspace
                                               trace log (obs_trace.jsonl)
    trace summary [--n N]                      per-stage latency breakdown
                                               (count/mean/p99/total) over the
                                               last N spans of the trace log
    status [--serve HOST:PORT]                 print maintain_status.json and a
                                               metrics snapshot; --serve blocks,
                                               serving /status, /metrics and
                                               /traces/recent over HTTP
    rm <lfn>
    verify <lfn>
    read <lfn> <offset> <len>
    meta <lfn>
    catalog compact [--budget-mb MB]           checkpoint every catalogue shard
                                               and GC sealed journal segments
                                               (at most MB of garbage removed)
    catalog stats                              per-shard journal health: segment
                                               count, live/garbage bytes, last
                                               checkpoint, ops since it
    lint [--json] [--update-baseline] [--rules k1,k2] [--root DIR]
                                               run the in-repo static analyzer
                                               (panic-freedom, unsafe hygiene,
                                               lock order, knob/metric drift,
                                               atomic writes) and compare with
                                               lint_baseline.json; exits nonzero
                                               on any regression
    se list
    se kill <name>
    se revive <name>
    durability [--p P]
    info
    help";

/// Parsed command line.
#[derive(Clone, Debug, PartialEq)]
pub struct Cli {
    /// Workspace directory (`--workspace`, default `drs-workspace`).
    pub workspace: String,
    /// The subcommand to run.
    pub command: Command,
}

/// One `drs` subcommand with its parsed arguments (see [`USAGE`]).
#[derive(Clone, Debug, PartialEq)]
#[allow(missing_docs)] // variants mirror USAGE one-to-one
pub enum Command {
    Init { ses: usize, k: usize, m: usize, vo: String },
    Put { local: String, lfn: String, workers: Option<usize>, k: Option<usize>, m: Option<usize>, retry: bool, stats: bool },
    Get { lfn: String, local: String, workers: Option<usize>, retry: bool, stats: bool },
    Ls { path: String },
    Stat { lfn: String },
    Repair { lfn: String, workers: Option<usize> },
    Scrub { root: String, workers: Option<usize>, shallow: bool, incremental: Option<usize> },
    RepairAll {
        root: String,
        workers: Option<usize>,
        max_files: Option<usize>,
        max_mb: Option<u64>,
        shallow: bool,
    },
    Drain { se: String, workers: Option<usize> },
    Serve { se: String, addr: String },
    Maintain {
        root: String,
        interval_s: Option<f64>,
        slice: Option<usize>,
        deep_every: Option<u64>,
        max_files: Option<usize>,
        max_mb: Option<u64>,
        workers: Option<usize>,
        ticks: Option<u64>,
        stop: bool,
        status_addr: Option<String>,
        drain_after: Option<u64>,
    },
    Trace { summary: bool, n: usize },
    Status { serve: Option<String> },
    Rm { lfn: String },
    Verify { lfn: String },
    Read { lfn: String, offset: u64, len: usize },
    Meta { lfn: String },
    CatalogCompact { budget_mb: Option<u64> },
    CatalogStats,
    Lint { json: bool, update_baseline: bool, rules: Option<String>, root: Option<String> },
    SeList,
    SeKill { name: String },
    SeRevive { name: String },
    Durability { p: f64 },
    Info,
    Help,
}

struct Args {
    items: Vec<String>,
    pos: usize,
}

impl Args {
    fn next(&mut self) -> Option<String> {
        let v = self.items.get(self.pos).cloned();
        if v.is_some() {
            self.pos += 1;
        }
        v
    }

    fn required(&mut self, what: &str) -> Result<String, String> {
        self.next().ok_or_else(|| format!("missing argument: <{what}>"))
    }

    /// Extract `--flag value` anywhere in the remaining args.
    fn opt_value(&mut self, flag: &str) -> Result<Option<String>, String> {
        if let Some(i) = self.items[self.pos..].iter().position(|a| a == flag) {
            let i = self.pos + i;
            if i + 1 >= self.items.len() {
                return Err(format!("{flag} needs a value"));
            }
            let v = self.items.remove(i + 1);
            self.items.remove(i);
            return Ok(Some(v));
        }
        Ok(None)
    }

    fn opt_parse<T: std::str::FromStr>(&mut self, flag: &str) -> Result<Option<T>, String> {
        match self.opt_value(flag)? {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| format!("bad value for {flag}: `{v}`")),
        }
    }

    /// Extract a boolean `--flag`.
    fn opt_flag(&mut self, flag: &str) -> bool {
        if let Some(i) = self.items[self.pos..].iter().position(|a| a == flag) {
            self.items.remove(self.pos + i);
            true
        } else {
            false
        }
    }
}

/// Parse argv (without the program name).
pub fn parse_args(argv: Vec<String>) -> Result<Cli, String> {
    let mut args = Args { items: argv, pos: 0 };
    let workspace = args
        .opt_value("--workspace")?
        .unwrap_or_else(|| "drs-workspace".to_string());

    let cmd = args.next().unwrap_or_else(|| "help".into());
    let command = match cmd.as_str() {
        "init" => Command::Init {
            ses: args.opt_parse("--ses")?.unwrap_or(15),
            k: args.opt_parse("--k")?.unwrap_or(10),
            m: args.opt_parse("--m")?.unwrap_or(5),
            vo: args.opt_value("--vo")?.unwrap_or_else(|| "demo".into()),
        },
        "put" => {
            let workers = args.opt_parse("--workers")?;
            let k = args.opt_parse("--k")?;
            let m = args.opt_parse("--m")?;
            let retry = args.opt_flag("--retry");
            let stats = args.opt_flag("--stats");
            Command::Put {
                local: args.required("local-file")?,
                lfn: args.required("lfn")?,
                workers,
                k,
                m,
                retry,
                stats,
            }
        }
        "get" => {
            let workers = args.opt_parse("--workers")?;
            let retry = args.opt_flag("--retry");
            let stats = args.opt_flag("--stats");
            Command::Get {
                lfn: args.required("lfn")?,
                local: args.required("local-file")?,
                workers,
                retry,
                stats,
            }
        }
        "ls" => Command::Ls { path: args.next().unwrap_or_else(|| "/".into()) },
        "stat" => Command::Stat { lfn: args.required("lfn")? },
        "repair" => {
            let workers = args.opt_parse("--workers")?;
            Command::Repair { lfn: args.required("lfn")?, workers }
        }
        "scrub" => Command::Scrub {
            root: args.opt_value("--root")?.unwrap_or_else(|| "/".into()),
            workers: args.opt_parse("--workers")?,
            shallow: args.opt_flag("--shallow"),
            incremental: args.opt_parse("--incremental")?,
        },
        "repair-all" => Command::RepairAll {
            root: args.opt_value("--root")?.unwrap_or_else(|| "/".into()),
            workers: args.opt_parse("--workers")?,
            max_files: args.opt_parse("--max-files")?,
            max_mb: args.opt_parse("--max-mb")?,
            shallow: args.opt_flag("--shallow"),
        },
        "drain" => {
            let workers = args.opt_parse("--workers")?;
            Command::Drain { se: args.required("se-name")?, workers }
        }
        "serve" => {
            let addr =
                args.opt_value("--addr")?.unwrap_or_else(|| "127.0.0.1:7070".into());
            Command::Serve { se: args.required("se-name")?, addr }
        }
        "maintain" => Command::Maintain {
            root: args.opt_value("--root")?.unwrap_or_else(|| "/".into()),
            interval_s: args.opt_parse("--interval-s")?,
            slice: args.opt_parse("--slice")?,
            deep_every: args.opt_parse("--deep-every")?,
            max_files: args.opt_parse("--max-files")?,
            max_mb: args.opt_parse("--max-mb")?,
            workers: args.opt_parse("--workers")?,
            ticks: args.opt_parse("--ticks")?,
            stop: args.opt_flag("--stop"),
            status_addr: args.opt_value("--status-addr")?,
            drain_after: args.opt_parse("--drain-after")?,
        },
        "trace" => {
            let n = args.opt_parse("--n")?.unwrap_or(200);
            match args.required("trace-subcommand")?.as_str() {
                "tail" => Command::Trace { summary: false, n },
                "summary" => Command::Trace { summary: true, n },
                other => return Err(format!("unknown trace subcommand `{other}`")),
            }
        }
        "status" => Command::Status { serve: args.opt_value("--serve")? },
        "rm" => Command::Rm { lfn: args.required("lfn")? },
        "verify" => Command::Verify { lfn: args.required("lfn")? },
        "read" => Command::Read {
            lfn: args.required("lfn")?,
            offset: args
                .required("offset")?
                .parse()
                .map_err(|_| "bad <offset>".to_string())?,
            len: args
                .required("len")?
                .parse()
                .map_err(|_| "bad <len>".to_string())?,
        },
        "meta" => Command::Meta { lfn: args.required("lfn")? },
        "catalog" => match args.required("catalog-subcommand")?.as_str() {
            "compact" => Command::CatalogCompact { budget_mb: args.opt_parse("--budget-mb")? },
            "stats" => Command::CatalogStats,
            other => return Err(format!("unknown catalog subcommand `{other}`")),
        },
        "lint" => Command::Lint {
            json: args.opt_flag("--json"),
            update_baseline: args.opt_flag("--update-baseline"),
            rules: args.opt_value("--rules")?,
            root: args.opt_value("--root")?,
        },
        "se" => match args.required("se-subcommand")?.as_str() {
            "list" => Command::SeList,
            "kill" => Command::SeKill { name: args.required("name")? },
            "revive" => Command::SeRevive { name: args.required("name")? },
            other => return Err(format!("unknown se subcommand `{other}`")),
        },
        "durability" => Command::Durability { p: args.opt_parse("--p")?.unwrap_or(0.9) },
        "info" => Command::Info,
        "help" | "--help" | "-h" => Command::Help,
        other => return Err(format!("unknown command `{other}`")),
    };
    Ok(Cli { workspace, command })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(s: &str) -> Result<Cli, String> {
        parse_args(s.split_whitespace().map(str::to_string).collect())
    }

    #[test]
    fn put_with_flags() {
        let cli = p("put f.dat /vo/f.dat --workers 5 --k 8 --m 2 --retry").unwrap();
        assert_eq!(
            cli.command,
            Command::Put {
                local: "f.dat".into(),
                lfn: "/vo/f.dat".into(),
                workers: Some(5),
                k: Some(8),
                m: Some(2),
                retry: true,
                stats: false
            }
        );
        assert!(matches!(
            p("put f.dat /vo/f.dat --stats").unwrap().command,
            Command::Put { stats: true, .. }
        ));
        assert!(matches!(
            p("get /vo/f.dat f.dat --stats").unwrap().command,
            Command::Get { stats: true, .. }
        ));
    }

    #[test]
    fn workspace_flag_anywhere() {
        let cli = p("--workspace /tmp/ws ls /vo").unwrap();
        assert_eq!(cli.workspace, "/tmp/ws");
        assert_eq!(cli.command, Command::Ls { path: "/vo".into() });
    }

    #[test]
    fn defaults() {
        assert_eq!(p("").unwrap().command, Command::Help);
        assert_eq!(p("ls").unwrap().command, Command::Ls { path: "/".into() });
        match p("init").unwrap().command {
            Command::Init { ses: 15, k: 10, m: 5, .. } => {}
            other => panic!("{other:?}"),
        }
        match p("durability").unwrap().command {
            Command::Durability { p } => assert_eq!(p, 0.9),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn maintenance_commands() {
        assert_eq!(
            p("scrub").unwrap().command,
            Command::Scrub { root: "/".into(), workers: None, shallow: false, incremental: None }
        );
        assert_eq!(
            p("scrub --root /vo/data --workers 8 --shallow").unwrap().command,
            Command::Scrub {
                root: "/vo/data".into(),
                workers: Some(8),
                shallow: true,
                incremental: None
            }
        );
        assert_eq!(
            p("scrub --incremental 25").unwrap().command,
            Command::Scrub { root: "/".into(), workers: None, shallow: false, incremental: Some(25) }
        );
        assert!(p("scrub --incremental many").is_err());
        assert_eq!(
            p("repair-all --max-files 10 --max-mb 500").unwrap().command,
            Command::RepairAll {
                root: "/".into(),
                workers: None,
                max_files: Some(10),
                max_mb: Some(500),
                shallow: false
            }
        );
        assert!(matches!(
            p("repair-all --shallow").unwrap().command,
            Command::RepairAll { shallow: true, .. }
        ));
        assert_eq!(
            p("drain SE-03 --workers 2").unwrap().command,
            Command::Drain { se: "SE-03".into(), workers: Some(2) }
        );
        assert!(p("drain").is_err());
        assert!(p("repair-all --max-files ten").is_err());
        // The usage text documents the new verbs next to `repair <lfn>`.
        for verb in ["scrub", "repair-all", "drain", "maintain"] {
            assert!(USAGE.contains(verb), "usage must document `{verb}`");
        }
    }

    #[test]
    fn maintain_command() {
        assert_eq!(
            p("maintain").unwrap().command,
            Command::Maintain {
                root: "/".into(),
                interval_s: None,
                slice: None,
                deep_every: None,
                max_files: None,
                max_mb: None,
                workers: None,
                ticks: None,
                stop: false,
                status_addr: None,
                drain_after: None,
            }
        );
        assert_eq!(
            p("maintain --root /vo --interval-s 0.5 --slice 16 --deep-every 3 \
               --max-files 4 --max-mb 100 --workers 2 --ticks 10 \
               --status-addr 127.0.0.1:9632 --drain-after 3")
            .unwrap()
            .command,
            Command::Maintain {
                root: "/vo".into(),
                interval_s: Some(0.5),
                slice: Some(16),
                deep_every: Some(3),
                max_files: Some(4),
                max_mb: Some(100),
                workers: Some(2),
                ticks: Some(10),
                stop: false,
                status_addr: Some("127.0.0.1:9632".into()),
                drain_after: Some(3),
            }
        );
        assert!(matches!(
            p("maintain --stop").unwrap().command,
            Command::Maintain { stop: true, .. }
        ));
        assert!(p("maintain --interval-s soon").is_err());
        assert!(p("maintain --ticks forever").is_err());
        assert!(p("maintain --drain-after never").is_err());
        assert!(USAGE.contains("maintain --stop"));
        assert!(USAGE.contains("--drain-after"));
    }

    #[test]
    fn serve_command() {
        assert_eq!(
            p("serve SE-03").unwrap().command,
            Command::Serve { se: "SE-03".into(), addr: "127.0.0.1:7070".into() }
        );
        assert_eq!(
            p("serve SE-03 --addr 0.0.0.0:9090").unwrap().command,
            Command::Serve { se: "SE-03".into(), addr: "0.0.0.0:9090".into() }
        );
        assert!(p("serve").is_err());
        assert!(p("serve SE-03 --addr").is_err());
        assert!(USAGE.contains("serve <se-name>"));
    }

    #[test]
    fn trace_and_status_commands() {
        assert_eq!(
            p("trace tail").unwrap().command,
            Command::Trace { summary: false, n: 200 }
        );
        assert_eq!(
            p("trace summary --n 50").unwrap().command,
            Command::Trace { summary: true, n: 50 }
        );
        assert!(p("trace").is_err());
        assert!(p("trace dance").is_err());
        assert!(p("trace tail --n lots").is_err());

        assert_eq!(p("status").unwrap().command, Command::Status { serve: None });
        assert_eq!(
            p("status --serve 0.0.0.0:8080").unwrap().command,
            Command::Status { serve: Some("0.0.0.0:8080".into()) }
        );
        assert!(p("status --serve").is_err());
        for verb in ["trace tail", "trace summary", "status", "--status-addr", "--stats"] {
            assert!(USAGE.contains(verb), "usage must document `{verb}`");
        }
    }

    #[test]
    fn catalog_subcommands() {
        assert_eq!(p("catalog stats").unwrap().command, Command::CatalogStats);
        assert_eq!(
            p("catalog compact").unwrap().command,
            Command::CatalogCompact { budget_mb: None }
        );
        assert_eq!(
            p("catalog compact --budget-mb 64").unwrap().command,
            Command::CatalogCompact { budget_mb: Some(64) }
        );
        assert!(p("catalog compact --budget-mb lots").is_err());
        assert!(p("catalog defrag").is_err());
        assert!(p("catalog").is_err());
        // The usage text documents the new verbs.
        for verb in ["catalog compact", "catalog stats"] {
            assert!(USAGE.contains(verb), "usage must document `{verb}`");
        }
    }

    #[test]
    fn lint_command() {
        assert_eq!(
            p("lint").unwrap().command,
            Command::Lint { json: false, update_baseline: false, rules: None, root: None }
        );
        assert_eq!(
            p("lint --json --rules panic,lock --root /repo").unwrap().command,
            Command::Lint {
                json: true,
                update_baseline: false,
                rules: Some("panic,lock".into()),
                root: Some("/repo".into()),
            }
        );
        assert!(matches!(
            p("lint --update-baseline").unwrap().command,
            Command::Lint { update_baseline: true, .. }
        ));
        assert!(p("lint --rules").is_err());
        assert!(USAGE.contains("lint [--json]"));
    }

    #[test]
    fn se_subcommands() {
        assert_eq!(p("se list").unwrap().command, Command::SeList);
        assert_eq!(
            p("se kill SE-03").unwrap().command,
            Command::SeKill { name: "SE-03".into() }
        );
        assert!(p("se explode").is_err());
    }

    #[test]
    fn errors() {
        assert!(p("put onlyone").is_err());
        assert!(p("put a b --workers abc").is_err());
        assert!(p("frobnicate").is_err());
        assert!(p("get x y --workers").is_err());
    }
}
