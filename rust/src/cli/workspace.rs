//! Workspace: the on-disk state the CLI operates on.
//!
//! Layout:
//! ```text
//! <workspace>/
//!   drs.json                     config (see config module)
//!   journal/shard-<i>/seg-<n>.log  catalogue write-ahead journal: every
//!                                mutation appends O(1) records to the
//!                                owning shard's segment log
//!   catalog.json.migrated        legacy whole-snapshot catalogue, kept
//!                                (renamed) after one-time migration
//!   ses/<NAME>/                  one directory per (local) storage element
//!   down_ses.json                names of SEs currently marked unavailable
//!   scrub_cursor.json            incremental-scrub resume point (shared by
//!                                `drs scrub --incremental` and `drs maintain`)
//!   maintain_status.json         `drs maintain` daemon status, rewritten
//!                                every tick
//!   maintain.stop                present while a daemon stop is pending
//!                                (`drs maintain --stop`)
//!   obs_trace.jsonl              structured span log, appended while the
//!                                `obs_trace` knob is on; rotated to
//!                                obs_trace.jsonl.1 at obs_trace_file_bytes
//! ```
//!
//! Opening a pre-journal workspace (a `catalog.json` and no `journal/`)
//! migrates transparently: the snapshot is loaded once, partitioned,
//! checkpointed into a fresh journal, and the legacy file renamed out of
//! the way. All small state files are written crash-safely via
//! [`crate::util::atomic_write`].

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::catalog::{Dfc, ShardedDfc};
use crate::config::Config;
use crate::dfm::{EcShim, ReplicationManager};
use crate::ec::{factory, BackendChoice, EcBackend};
use crate::runtime::PjrtBackend;
use crate::se::{LocalSe, RemoteSe, SeRegistry, StorageElement};
use crate::util::json::Json;
use crate::{Error, Result};

/// The on-disk state the CLI operates on.
pub struct Workspace {
    /// Workspace directory.
    pub root: PathBuf,
    /// Parsed `drs.json`.
    pub config: Config,
    /// The catalogue, partitioned over `config.catalog_shards` shards.
    pub dfc: Arc<ShardedDfc>,
    /// The registered (local, directory-backed) storage elements.
    pub registry: Arc<SeRegistry>,
    backend_name: &'static str,
    backend: Arc<dyn EcBackend>,
    /// Process-wide read cache, shared by every shim this workspace
    /// hands out (sized by `cache_bytes` / `cache_degraded_bytes`).
    cache: Arc<crate::cache::ReadCache>,
}

impl Workspace {
    /// Create a fresh workspace (fails if a config already exists).
    pub fn init(root: &Path, config: Config) -> Result<Self> {
        if root.join("drs.json").exists() {
            return Err(Error::Config(format!(
                "workspace already initialized at {}",
                root.display()
            )));
        }
        std::fs::create_dir_all(root.join("ses"))?;
        config.save(&root.join("drs.json"))?;
        crate::util::atomic_write(&root.join("down_ses.json"), b"[]")?;
        Self::open(root)
    }

    /// Open an existing workspace, recovering the catalogue from its
    /// per-shard journal (or migrating a legacy `catalog.json` into a
    /// fresh journal on first open).
    pub fn open(root: &Path) -> Result<Self> {
        let config = Config::load(&root.join("drs.json"))?;
        if config.obs_trace {
            // Wire tracing before the catalogue opens so journal spans
            // from recovery/migration land in the trace too.
            let t = crate::obs::tracer();
            t.set_buffer(config.obs_trace_buffer);
            t.attach_sink(&root.join("obs_trace.jsonl"), config.obs_trace_file_bytes)?;
            t.set_enabled(true);
        }
        let journal_dir = root.join("journal");
        let legacy = root.join("catalog.json");
        if !journal_dir.is_dir() && legacy.exists() {
            // One-time migration from the whole-snapshot format: load,
            // partition, checkpoint into a *staging* journal, then
            // atomically move it into place and retire the legacy file.
            // A crash at any point leaves either a readable legacy
            // snapshot (migration re-runs) or a complete journal.
            let staging = root.join("journal.migrating");
            let _ = std::fs::remove_dir_all(&staging);
            let mut migrated =
                ShardedDfc::from_dfc(&Dfc::load(&legacy)?, config.catalog_shards)?;
            migrated.attach_journal(&staging, config.journal())?;
            drop(migrated); // close staging writers before the rename
            std::fs::rename(&staging, &journal_dir)?;
        }
        if journal_dir.is_dir() && legacy.exists() {
            // Retire the legacy snapshot (also heals a crash that landed
            // between the two renames on a previous open).
            std::fs::rename(&legacy, root.join("catalog.json.migrated"))?;
        }
        let dfc =
            ShardedDfc::open_journaled(&journal_dir, config.catalog_shards, config.journal())?;
        let down: Vec<String> = std::fs::read_to_string(root.join("down_ses.json"))
            .ok()
            .and_then(|t| Json::parse(&t).ok())
            .and_then(|j| {
                j.as_arr().map(|a| {
                    a.iter().filter_map(|v| v.as_str().map(str::to_string)).collect()
                })
            })
            .unwrap_or_default();

        let mut registry = SeRegistry::new();
        for se_cfg in &config.ses {
            // An `endpoint` entry makes the SE a network client to a
            // `drs serve` instance; everything downstream (shim, repair,
            // drain, scrub) sees the same `StorageElement` trait either
            // way. Construction never dials — a dark endpoint only
            // surfaces when the SE is actually used.
            let se: Arc<dyn StorageElement> = match &se_cfg.endpoint {
                Some(endpoint) => Arc::new(RemoteSe::new(
                    &se_cfg.name,
                    &se_cfg.region,
                    endpoint,
                    config.remote_options(),
                )),
                None => Arc::new(LocalSe::new(
                    &se_cfg.name,
                    &se_cfg.region,
                    root.join("ses").join(&se_cfg.name),
                )?),
            };
            if down.contains(&se_cfg.name) {
                se.set_available(false);
            }
            registry.register(se, &[config.vo.as_str()])?;
        }

        // Select the coding backend. `auto` prefers the AOT/PJRT backend
        // when its artifacts exist, then the fastest SIMD kernel this CPU
        // supports, then scalar. An explicit `ec_backend` knob (or
        // `DRS_EC_BACKEND`) pins the choice instead — and fails loudly if
        // the CPU can't deliver it.
        let (backend, backend_name): (Arc<dyn EcBackend>, &'static str) =
            match config.ec_backend {
                BackendChoice::Auto => match PjrtBackend::from_default_dir() {
                    Ok(b) => (Arc::new(b), "pjrt-aot"),
                    Err(_) => {
                        let b = factory::auto();
                        let name = b.name();
                        (b, name)
                    }
                },
                forced => {
                    let b = factory::select(forced)?;
                    let name = b.name();
                    (b, name)
                }
            };
        // Surface the selection in metrics (and thus `drs status` /
        // the Prometheus endpoint): `ec.backend.<name>` = 1.
        crate::metrics::global().gauge(&format!("ec.backend.{backend_name}"), 1.0);

        let cache = Arc::new(crate::cache::ReadCache::new(
            config.cache_bytes,
            config.cache_degraded_bytes,
        ));

        Ok(Workspace {
            root: root.to_path_buf(),
            config,
            dfc: Arc::new(dfc),
            registry: Arc::new(registry),
            backend_name,
            backend,
            cache,
        })
    }

    /// The workspace's shared read cache (for `drs status` reporting).
    pub fn cache(&self) -> Arc<crate::cache::ReadCache> {
        Arc::clone(&self.cache)
    }

    /// Which coding backend `open` selected (`pjrt-aot`, `avx2`,
    /// `ssse3` or `scalar`).
    pub fn backend_name(&self) -> &'static str {
        self.backend_name
    }

    /// An erasure-coding shim wired over this workspace.
    pub fn shim(&self) -> EcShim {
        let policy = self
            .config
            .policy
            .build(&self.config.client_region, self.config.params.n());
        EcShim::with_cache(
            Arc::clone(&self.dfc),
            Arc::clone(&self.registry),
            policy,
            Arc::clone(&self.backend),
            self.config.vo.clone(),
            Arc::clone(&self.cache),
        )
    }

    /// The whole-file replication baseline over this workspace.
    pub fn replication(&self) -> ReplicationManager {
        let policy = self
            .config
            .policy
            .build(&self.config.client_region, self.config.params.n());
        ReplicationManager::new(
            Arc::clone(&self.dfc),
            Arc::clone(&self.registry),
            policy,
            self.config.vo.clone(),
        )
    }

    /// Incremental-scrub cursor from the previous `scrub --incremental`
    /// or `drs maintain` run *for the same scrub root*: the last EC
    /// directory examined, or `None` when the previous walk completed, no
    /// cursor has been saved yet, or the saved cursor belongs to a
    /// different root (a cursor from `/vo/b` must not filter a walk of
    /// `/vo/a`). Delegates to [`crate::maintenance::daemon::load_scrub_cursor`]
    /// so manual scrubs and the daemon share one resume point.
    pub fn load_scrub_cursor(&self, scrub_root: &str) -> Option<String> {
        crate::maintenance::daemon::load_scrub_cursor(&self.root, scrub_root)
    }

    /// Persist (or clear, with `None`) the incremental-scrub cursor,
    /// tagged with the scrub root it belongs to.
    pub fn save_scrub_cursor(&self, scrub_root: &str, cursor: Option<&str>) -> Result<()> {
        crate::maintenance::daemon::save_scrub_cursor(&self.root, scrub_root, cursor)
    }

    /// How much sealed journal garbage one post-command housekeeping
    /// pass may reclaim. Small enough that `save` stays O(1)-ish; the
    /// rest is left for the next command or `drs catalog compact`.
    const SAVE_GC_BUDGET: u64 = 4 << 20;

    /// Persist SE availability after a mutating command and do a bounded
    /// pass of journal housekeeping. The catalogue itself needs no save:
    /// every mutation was already appended to its shard's write-ahead
    /// journal when it happened.
    pub fn save(&self) -> Result<()> {
        let _ = self.dfc.journal_gc(Self::SAVE_GC_BUDGET)?;
        let down: Vec<Json> = self
            .registry
            .all()
            .iter()
            .filter(|se| !se.is_available())
            .map(|se| Json::str(se.name()))
            .collect();
        crate::util::atomic_write(
            &self.root.join("down_ses.json"),
            Json::Arr(down).to_string().as_bytes(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "drs-ws-{tag}-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ))
    }

    #[test]
    fn init_open_cycle() {
        let root = tmp("cycle");
        let mut cfg = Config::default();
        cfg.ses.truncate(4);
        let ws = Workspace::init(&root, cfg).unwrap();
        assert_eq!(ws.registry.len(), 4);
        // double init rejected
        assert!(Workspace::init(&root, Config::default()).is_err());
        drop(ws);
        let ws2 = Workspace::open(&root).unwrap();
        assert_eq!(ws2.config.ses.len(), 4);
        std::fs::remove_dir_all(root).unwrap();
    }

    #[test]
    fn backend_selection_forced_auto_and_metrics() {
        // Forced scalar: honored, named, and surfaced through metrics.
        let root = tmp("backend-scalar");
        let mut cfg = Config::default();
        cfg.ses.truncate(2);
        cfg.ec_backend = BackendChoice::Scalar;
        let ws = Workspace::init(&root, cfg).unwrap();
        assert_eq!(ws.backend_name(), "scalar");
        assert!(crate::metrics::global()
            .gauges()
            .iter()
            .any(|(n, v)| n == "ec.backend.scalar" && *v == 1.0));
        drop(ws);
        std::fs::remove_dir_all(&root).unwrap();

        // Auto (no pjrt artifacts in test workspaces): resolves to the
        // factory's pick for this CPU.
        let root = tmp("backend-auto");
        let mut cfg = Config::default();
        cfg.ses.truncate(2);
        let ws = Workspace::init(&root, cfg).unwrap();
        let expected = factory::resolve(BackendChoice::Auto, crate::ec::CpuCaps::detect())
            .unwrap();
        assert_eq!(ws.backend_name(), expected);
        drop(ws);
        std::fs::remove_dir_all(&root).unwrap();

        // Forcing a SIMD backend: honored when the CPU has it, a clear
        // config error otherwise (never a silent fallback).
        let caps = crate::ec::CpuCaps::detect();
        let root = tmp("backend-avx2");
        let mut cfg = Config::default();
        cfg.ses.truncate(2);
        cfg.ec_backend = BackendChoice::Avx2;
        match Workspace::init(&root, cfg) {
            Ok(ws) => {
                assert!(caps.avx2);
                assert_eq!(ws.backend_name(), "avx2");
            }
            Err(e) => {
                assert!(!caps.avx2);
                assert!(e.to_string().contains("avx2"));
            }
        }
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn scrub_cursor_roundtrip() {
        let root = tmp("cursor");
        let mut cfg = Config::default();
        cfg.ses.truncate(2);
        let ws = Workspace::init(&root, cfg).unwrap();
        assert_eq!(ws.load_scrub_cursor("/"), None);
        ws.save_scrub_cursor("/", Some("/vo/data/f9.ec")).unwrap();
        assert_eq!(ws.load_scrub_cursor("/"), Some("/vo/data/f9.ec".to_string()));
        // A cursor is bound to its root: a different root ignores it.
        assert_eq!(ws.load_scrub_cursor("/vo/other"), None);
        ws.save_scrub_cursor("/", None).unwrap();
        assert_eq!(ws.load_scrub_cursor("/"), None);
        std::fs::remove_dir_all(root).unwrap();
    }

    #[test]
    fn endpoint_ses_route_over_the_wire() {
        use crate::se::{ChunkServer, MemSe, ServeOptions};
        // A `drs serve` stand-in for SE-01; the workspace only knows its
        // address.
        let backing = Arc::new(MemSe::new("SE-01", "uk"));
        let srv =
            ChunkServer::serve(backing, "127.0.0.1:0", ServeOptions::default()).unwrap();

        let root = tmp("remote");
        let mut cfg = Config::default();
        cfg.ses.truncate(4);
        cfg.params = crate::ec::EcParams::new(2, 1).unwrap();
        cfg.stripe_b = 512;
        cfg.ses[1].endpoint = Some(srv.addr().to_string());
        let ws = Workspace::init(&root, cfg).unwrap();

        let remote = ws.registry.get("SE-01").unwrap();
        assert!(remote.transport_detail().unwrap().contains("endpoint="));

        let shim = ws.shim();
        let data: Vec<u8> = (0..40_000u32).map(|i| (i % 251) as u8).collect();
        let opts = crate::dfm::PutOptions::default()
            .with_params(ws.config.params)
            .with_stripe(ws.config.stripe_b);
        shim.put_bytes("/vo/remote.bin", &data, &opts).unwrap();
        assert!(remote.used_bytes() > 0, "remote SE should hold chunks");
        let back =
            shim.get_bytes("/vo/remote.bin", &crate::dfm::GetOptions::default()).unwrap();
        assert_eq!(back, data);

        drop(shim);
        drop(ws);
        srv.stop();
        std::fs::remove_dir_all(root).unwrap();
    }

    #[test]
    fn state_persists_across_open() {
        let root = tmp("persist");
        let mut cfg = Config::default();
        cfg.ses.truncate(6);
        cfg.params = crate::ec::EcParams::new(4, 2).unwrap();
        cfg.stripe_b = 1024;
        let ws = Workspace::init(&root, cfg).unwrap();
        let shim = ws.shim();
        let data = vec![0xA5u8; 20_000];
        let opts = crate::dfm::PutOptions::default()
            .with_params(ws.config.params)
            .with_stripe(ws.config.stripe_b);
        shim.put_bytes("/vo/persist.bin", &data, &opts).unwrap();
        ws.registry.get("SE-02").unwrap().set_available(false);
        ws.save().unwrap();
        drop(shim);
        drop(ws);

        let ws2 = Workspace::open(&root).unwrap();
        assert!(!ws2.registry.get("SE-02").unwrap().is_available());
        let back = ws2
            .shim()
            .get_bytes("/vo/persist.bin", &crate::dfm::GetOptions::default())
            .unwrap();
        assert_eq!(back, data);
        std::fs::remove_dir_all(root).unwrap();
    }
}
