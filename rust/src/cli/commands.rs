//! Command implementations.

use std::path::Path;

use crate::config::Config;
use crate::dfm::{GetOptions, PutOptions};
use crate::ec::EcParams;
use crate::maintenance::daemon::{self, Daemon, DaemonOptions, StopToken};
use crate::maintenance::{DrainOptions, Maintainer, RepairBudget, ScrubOptions};
use crate::obs::http::{StatusFn, StatusServer};
use crate::obs::summary::{self as trace_summary, TraceEvent};
use crate::sim::durability;
use crate::transfer::RetryPolicy;
use crate::util::json::Json;
use crate::util::{fmt_bytes, fmt_secs};
use crate::{Error, Result};

use super::args::{Cli, Command, USAGE};
use super::workspace::Workspace;

/// Audit every chunk of `lfn` against its catalog checksum without
/// reconstructing the file. Chunks are hashed block-by-block through
/// [`crate::se::hash_object`], so even huge chunks are never
/// materialized in memory.
fn verify_chunks(ws: &Workspace, lfn: &str) -> Result<(usize, usize)> {
    let block = ws.config.transfer_block_bytes;
    let items = ws.dfc.list_dir(lfn)?;
    let (mut ok, mut bad) = (0usize, 0usize);
    for item in items {
        let crate::catalog::dfc::DirItem::File(name) = item else { continue };
        let path = format!("{lfn}/{name}");
        let replicas = ws.dfc.replicas(&path)?;
        let want = ws.dfc.file(&path)?.checksum;
        let mut good = false;
        for r in &replicas {
            if let Some(se) = ws.registry.get(&r.se) {
                if let Ok(digest) = crate::se::hash_object(se.as_ref(), &r.pfn, block) {
                    if crate::util::hexfmt::encode(&digest) == want {
                        good = true;
                        break;
                    }
                }
            }
        }
        if good {
            ok += 1;
        } else {
            bad += 1;
            eprintln!("  corrupt/missing: {name}");
        }
    }
    Ok((ok, bad))
}

/// Print the `--stats` per-stage breakdown for a finished transfer:
/// the spans of its trace, pulled from the in-process ring buffer.
/// Tracing off (trace id 0) prints a hint instead.
fn print_transfer_breakdown(stats: &crate::dfm::StreamStats) {
    if stats.trace_id == 0 {
        println!(
            "  (no trace: set `obs_trace` in drs.json or DRS_OBS_TRACE=1 \
             for a per-stage breakdown)"
        );
        return;
    }
    let events: Vec<TraceEvent> = crate::obs::tracer()
        .recent_for(stats.trace_id)
        .iter()
        .map(TraceEvent::from_record)
        .collect();
    print!("{}", trace_summary::render_trace_breakdown(&events));
}

/// Read the workspace's trace log (rotated file first, so events stay
/// in chronological order) and keep the newest `n` events.
fn load_trace_events(ws: &Workspace, n: usize) -> Result<Vec<TraceEvent>> {
    // Anything this process traced but not yet flushed should be
    // visible to its own `trace` subcommand.
    crate::obs::tracer().flush();
    let log = ws.root.join("obs_trace.jsonl");
    let mut text = std::fs::read_to_string(ws.root.join("obs_trace.jsonl.1")).unwrap_or_default();
    match std::fs::read_to_string(&log) {
        Ok(t) => text.push_str(&t),
        Err(_) if !text.is_empty() => {}
        Err(_) => {
            return Err(Error::Config(format!(
                "no trace log at {} — set `obs_trace` in drs.json (or DRS_OBS_TRACE=1) \
                 and run some transfers first",
                log.display()
            )))
        }
    }
    let mut events = trace_summary::parse_jsonl(&text);
    if events.len() > n {
        events.drain(..events.len() - n);
    }
    Ok(events)
}

/// Execute one parsed command against its workspace.
pub fn dispatch(cli: &Cli) -> Result<()> {
    let root = Path::new(&cli.workspace);
    match &cli.command {
        Command::Help => {
            println!("{USAGE}");
            Ok(())
        }
        Command::Init { ses, k, m, vo } => {
            let mut cfg = Config::default();
            cfg.vo = vo.clone();
            cfg.params = EcParams::new(*k, *m)?;
            cfg.ses = (0..*ses)
                .map(|i| crate::config::SeConfig {
                    name: format!("SE-{i:02}"),
                    region: ["uk", "fr", "de"][i % 3].into(),
                    endpoint: None,
                })
                .collect();
            let ws = Workspace::init(root, cfg)?;
            println!(
                "initialized workspace at {} ({} SEs, EC {}, vo {}, backend {})",
                root.display(),
                ws.registry.len(),
                ws.config.params,
                ws.config.vo,
                ws.backend_name()
            );
            ws.save()
        }
        Command::Put { local, lfn, workers, k, m, retry, stats: show_stats } => {
            let ws = Workspace::open(root)?;
            let size = std::fs::metadata(local)?.len();
            let params = match (k, m) {
                (Some(k), Some(m)) => EcParams::new(*k, *m)?,
                (Some(k), None) => EcParams::new(*k, ws.config.params.m())?,
                (None, Some(m)) => EcParams::new(ws.config.params.k(), *m)?,
                (None, None) => ws.config.params,
            };
            let opts = PutOptions::default()
                .with_params(params)
                .with_stripe(ws.config.stripe_b)
                .with_workers(workers.unwrap_or(ws.config.workers))
                .with_block_bytes(ws.config.transfer_block_bytes)
                .with_retry(if *retry {
                    RetryPolicy::default_robust()
                } else {
                    RetryPolicy::none()
                });
            let t0 = std::time::Instant::now();
            // Streamed: the file is encoded and uploaded block-by-block
            // (O(N·block) memory), never read into RAM whole.
            let (placed, stats) =
                ws.shim().put_file_stats(lfn, Path::new(local), &opts)?;
            let dt = t0.elapsed().as_secs_f64();
            println!(
                "put {} ({}) as {} chunks ({params}) in {} [{:.1} MB/s] via {} \
                 [streamed: {} blocks, {} stalls, peak {}]",
                lfn,
                fmt_bytes(size),
                placed.len(),
                fmt_secs(dt),
                size as f64 / dt.max(1e-9) / 1e6,
                ws.backend_name(),
                stats.blocks,
                stats.stalls,
                fmt_bytes(stats.peak_buffered_bytes),
            );
            for (i, se) in placed.iter().enumerate() {
                println!("  chunk {i:02} -> {se}");
            }
            if *show_stats {
                print_transfer_breakdown(&stats);
            }
            ws.save()
        }
        Command::Get { lfn, local, workers, retry, stats: show_stats } => {
            let ws = Workspace::open(root)?;
            let opts = GetOptions::default()
                .with_workers(workers.unwrap_or(ws.config.workers))
                .with_block_bytes(ws.config.transfer_block_bytes)
                .with_retry(if *retry {
                    RetryPolicy::default_robust()
                } else {
                    RetryPolicy::none()
                });
            let t0 = std::time::Instant::now();
            // Streamed: parallel same-offset block fetches across K
            // chunks, decoded straight into the destination file.
            let (bytes, stats) = ws.shim().get_file_stats(lfn, Path::new(local), &opts)?;
            let dt = t0.elapsed().as_secs_f64();
            println!(
                "got {} ({}) in {} [{:.1} MB/s], SHA-verified",
                lfn,
                fmt_bytes(bytes),
                fmt_secs(dt),
                bytes as f64 / dt.max(1e-9) / 1e6
            );
            if *show_stats {
                print_transfer_breakdown(&stats);
            }
            Ok(())
        }
        Command::Ls { path } => {
            let ws = Workspace::open(root)?;
            for item in ws.dfc.list_dir(path)? {
                match item {
                    crate::catalog::dfc::DirItem::Dir(n) => println!("d {n}"),
                    crate::catalog::dfc::DirItem::File(n) => println!("f {n}"),
                }
            }
            Ok(())
        }
        Command::Stat { lfn } => {
            let ws = Workspace::open(root)?;
            let stat = ws.shim().stat(lfn)?;
            println!(
                "{}: EC {} stripe {} — {}/{} chunks available ({})",
                stat.lfn,
                stat.params,
                stat.stripe_b,
                stat.available_chunks,
                stat.chunks.len(),
                if stat.readable() { "READABLE" } else { "LOST" }
            );
            for c in &stat.chunks {
                println!(
                    "  [{}] chunk {:02} on {} {}",
                    if c.available { "ok" } else { "XX" },
                    c.index,
                    c.se,
                    if c.available { "" } else { "(unavailable)" }
                );
            }
            Ok(())
        }
        Command::Repair { lfn, workers } => {
            let ws = Workspace::open(root)?;
            let opts = GetOptions::default()
                .with_workers(workers.unwrap_or(ws.config.workers))
                .with_block_bytes(ws.config.transfer_block_bytes);
            let n = ws.shim().repair(lfn, &opts)?;
            println!("repaired {n} chunk(s) of {lfn}");
            ws.save()
        }
        Command::Scrub { root: scrub_root, workers, shallow, incremental } => {
            let ws = Workspace::open(root)?;
            let shim = ws.shim();
            let maintainer = Maintainer::new(&shim);
            let mut opts = ScrubOptions::default()
                .with_root(scrub_root.clone())
                .with_workers(workers.unwrap_or(ws.config.workers));
            if *shallow {
                opts = opts.shallow();
            }
            if let Some(n) = incremental {
                opts = opts.with_max_dirs(*n);
                if let Some(cursor) = ws.load_scrub_cursor(scrub_root) {
                    opts = opts.resume_after(cursor);
                }
            }
            let t0 = std::time::Instant::now();
            let report = maintainer.scrub(&opts)?;
            for f in &report.files {
                if f.needs_repair() {
                    println!(
                        "  [{}] {} — {}/{} chunks, margin {} (missing {:?}, {} corrupt)",
                        match f.state() {
                            crate::maintenance::HealthState::Lost => "LOST",
                            _ => "DEGR",
                        },
                        f.lfn,
                        f.available,
                        f.n,
                        f.margin(),
                        f.missing,
                        f.corrupt.len()
                    );
                }
            }
            for (lfn, why) in &report.skipped {
                eprintln!("  skipped {lfn}: {why}");
            }
            println!("scrub: {} in {}", report.summary(), fmt_secs(t0.elapsed().as_secs_f64()));
            if incremental.is_some() {
                ws.save_scrub_cursor(scrub_root, report.cursor.as_deref())?;
                match &report.cursor {
                    Some(c) => println!(
                        "incremental: stopped after `{c}`; cursor saved, next run resumes there"
                    ),
                    None => println!("incremental: walk complete; cursor reset to the start"),
                }
            }
            Ok(())
        }
        Command::RepairAll { root: scrub_root, workers, max_files, max_mb, shallow } => {
            let ws = Workspace::open(root)?;
            let shim = ws.shim();
            let maintainer = Maintainer::new(&shim);
            let mut opts = ScrubOptions::default()
                .with_root(scrub_root.clone())
                .with_workers(workers.unwrap_or(ws.config.workers));
            if *shallow {
                opts = opts.shallow();
            }
            let mut budget = RepairBudget::default()
                .with_workers(workers.unwrap_or(ws.config.workers))
                .with_block_bytes(ws.config.transfer_block_bytes);
            if let Some(n) = max_files {
                budget = budget.with_max_files(*n);
            }
            if let Some(mb) = max_mb {
                budget = budget.with_max_bytes(mb.saturating_mul(1_000_000));
            }
            let t0 = std::time::Instant::now();
            let (before, summary, after) = maintainer.scrub_and_repair(&opts, &budget)?;
            println!("before: {}", before.summary());
            for o in &summary.outcomes {
                match &o.error {
                    None => println!(
                        "  repaired {} (+{} chunks, margin was {})",
                        o.lfn, o.chunks_rebuilt, o.margin_before
                    ),
                    Some(e) => println!("  FAILED {}: {e}", o.lfn),
                }
            }
            for lfn in &summary.deferred {
                println!("  deferred (budget): {lfn}");
            }
            for lfn in &summary.lost {
                println!("  LOST (unrepairable): {lfn}");
            }
            println!(
                "after (repaired files only): {}; {} deferred, {} lost remain",
                after.summary(),
                summary.deferred.len(),
                summary.lost.len()
            );
            println!(
                "repair-all: {} in {}",
                summary.summary(),
                fmt_secs(t0.elapsed().as_secs_f64())
            );
            ws.save()?;
            if summary.files_failed > 0 {
                return Err(Error::Transfer(format!(
                    "{} file(s) failed to repair",
                    summary.files_failed
                )));
            }
            Ok(())
        }
        Command::Drain { se, workers } => {
            let ws = Workspace::open(root)?;
            let shim = ws.shim();
            let maintainer = Maintainer::new(&shim);
            let opts = DrainOptions::default()
                .with_workers(workers.unwrap_or(ws.config.workers))
                .with_block_bytes(ws.config.transfer_block_bytes);
            let t0 = std::time::Instant::now();
            let report = maintainer.drain(se, &opts)?;
            for (path, err) in &report.failures {
                eprintln!("  failed: {path}: {err}");
            }
            if report.residual_objects > 0 {
                eprintln!(
                    "  warning: {} uncatalogued object(s) remain on {se}",
                    report.residual_objects
                );
            }
            println!("{} in {}", report.summary(), fmt_secs(t0.elapsed().as_secs_f64()));
            ws.save()?;
            if !report.clean() {
                return Err(Error::Transfer(format!(
                    "drain of `{se}` incomplete ({} replica(s) not evacuated)",
                    report.failures.len()
                )));
            }
            Ok(())
        }
        Command::Serve { se, addr } => {
            let ws = Workspace::open(root)?;
            let target = ws
                .registry
                .get(se)
                .ok_or_else(|| Error::Config(format!("no such SE `{se}`")))?;
            if target.transport_detail().is_some() {
                // Serving an endpoint-backed SE would make this process a
                // blind proxy to another server; point clients there
                // directly instead.
                return Err(Error::Config(format!(
                    "SE `{se}` is itself remote ({}); serve it from the \
                     workspace that holds its chunks",
                    target.transport_detail().unwrap_or_default()
                )));
            }
            let opts = crate::se::ServeOptions {
                io_timeout: std::time::Duration::from_millis(ws.config.remote_io_timeout_ms),
                ..crate::se::ServeOptions::default()
            };
            let server = crate::se::ChunkServer::serve(target, addr, opts)?;
            let stop_token = StopToken::new();
            stop_token.hook_signals();
            println!(
                "serving SE `{se}` on {} (chunk protocol v{}); point remote \
                 workspaces' `endpoint` at this address; SIGINT/SIGTERM to stop",
                server.addr(),
                crate::se::proto::PROTO_VERSION,
            );
            while !stop_token.should_stop() {
                std::thread::sleep(std::time::Duration::from_millis(100));
            }
            server.stop();
            println!("chunk server stopped ({})", stop_token.cause().unwrap_or("signal"));
            ws.save()
        }
        Command::Maintain {
            root: scrub_root,
            interval_s,
            slice,
            deep_every,
            max_files,
            max_mb,
            workers,
            ticks,
            stop,
            status_addr,
            drain_after,
        } => {
            let ws = Workspace::open(root)?;
            let stop_path = daemon::stop_file_path(&ws.root);
            if *stop {
                crate::util::atomic_write(&stop_path, b"stop\n")?;
                println!(
                    "stop requested: wrote {} (the daemon finishes its in-flight pass, \
                     dumps a final status and removes the file)",
                    stop_path.display()
                );
                return Ok(());
            }
            let cfg = &ws.config;
            let mut budget = RepairBudget::default()
                .with_workers(workers.unwrap_or(cfg.workers))
                .with_block_bytes(cfg.transfer_block_bytes);
            let files_cap = max_files.unwrap_or(cfg.maintain_repair_budget_files);
            if files_cap > 0 {
                budget = budget.with_max_files(files_cap);
            }
            let mb_cap = max_mb.unwrap_or(cfg.maintain_repair_budget_mb);
            if mb_cap > 0 {
                budget = budget.with_max_bytes(mb_cap.saturating_mul(1_000_000));
            }
            let interval = interval_s.unwrap_or(cfg.maintain_scrub_interval_s).max(0.0);
            let interval_d = std::time::Duration::try_from_secs_f64(interval)
                .map_err(|e| Error::Config(format!("bad maintain interval {interval}: {e}")))?;
            // CLI flag wins; the `obs_status_addr` knob is the default.
            let addr = status_addr.clone().or_else(|| {
                (!cfg.obs_status_addr.is_empty()).then(|| cfg.obs_status_addr.clone())
            });
            let opts = DaemonOptions::default()
                .with_root(scrub_root.clone())
                .with_interval(interval_d)
                .with_slice(slice.unwrap_or(cfg.maintain_scrub_slice))
                .with_deep_every(deep_every.unwrap_or(cfg.maintain_deep_every))
                .with_budget(budget)
                .with_workers(workers.unwrap_or(cfg.workers))
                .with_max_ticks(*ticks)
                .with_status_addr(addr)
                .with_drain_after_passes(
                    drain_after.unwrap_or(cfg.maintain_drain_after_passes),
                );
            let shim = ws.shim();
            let stop_token = StopToken::with_stop_file(&stop_path);
            stop_token.hook_signals();
            println!(
                "maintenance daemon: root {} every {interval}s, slice {}, deep every {} \
                 pass(es); status {}{}; stop with SIGINT/SIGTERM or `drs maintain --stop`",
                opts.root,
                opts.scrub_slice,
                opts.deep_every,
                daemon::status_path(&ws.root).display(),
                opts.status_addr
                    .as_deref()
                    .map(|a| format!(" + http://{a}/status"))
                    .unwrap_or_default()
            );
            let report = Daemon::new(&shim, opts, ws.root.clone()).run(&stop_token)?;
            println!("daemon exit ({}): {}", report.stopped_by, report.summary());
            ws.save()
        }
        Command::Trace { summary: want_summary, n } => {
            let ws = Workspace::open(root)?;
            let events = load_trace_events(&ws, *n)?;
            if *want_summary {
                print!("{}", trace_summary::Summary::build(&events).render(&events));
            } else {
                for e in &events {
                    println!("{}", e.render_line());
                }
            }
            Ok(())
        }
        Command::Status { serve } => {
            let ws = Workspace::open(root)?;
            let status_file = daemon::status_path(&ws.root);
            match serve {
                Some(addr) => {
                    // Serve the on-disk daemon status, re-read per
                    // request: this process is a window onto a daemon
                    // running elsewhere, so nothing is cached.
                    let path = status_file.clone();
                    let status: StatusFn = std::sync::Arc::new(move || {
                        std::fs::read_to_string(&path)
                            .ok()
                            .and_then(|t| Json::parse(&t).ok())
                            .unwrap_or_else(|| {
                                Json::obj(vec![("phase", Json::str("no-daemon"))])
                            })
                    });
                    let server = StatusServer::serve(addr, status)?;
                    println!(
                        "serving http://{} (GET /status, /metrics, /traces/recent); \
                         Ctrl-C to quit",
                        server.local_addr()
                    );
                    // The endpoint *is* the command; block until killed.
                    loop {
                        std::thread::sleep(std::time::Duration::from_secs(60));
                    }
                }
                None => {
                    println!("codec backend: {}", ws.backend_name());
                    let cache = ws.cache();
                    if cache.enabled() || cache.degraded_enabled() {
                        let cs = cache.stats();
                        println!(
                            "read cache: {} blocks + {} degraded caps; \
                             {} hits / {} misses ({:.0}% hit rate), \
                             resident {} (peak {}), degraded {} (peak {}), \
                             {} chunk(s) repair-adopted",
                            fmt_bytes(cache.capacity_bytes()),
                            fmt_bytes(cache.degraded_capacity_bytes()),
                            cs.hits,
                            cs.misses,
                            cs.hit_rate() * 100.0,
                            fmt_bytes(cs.resident_bytes),
                            fmt_bytes(cs.peak_resident_bytes),
                            fmt_bytes(cs.degraded_resident_bytes),
                            fmt_bytes(cs.peak_degraded_resident_bytes),
                            cs.adopted_chunks
                        );
                    } else {
                        println!(
                            "read cache: disabled (set `cache_bytes` / \
                             `cache_degraded_bytes` in drs.json or DRS_CACHE_BYTES)"
                        );
                    }
                    match std::fs::read_to_string(&status_file) {
                        Ok(text) => println!("{text}"),
                        Err(_) => println!(
                            "(no {} yet — is `drs maintain` running?)",
                            status_file.display()
                        ),
                    }
                    print!("{}", crate::metrics::global().report());
                    Ok(())
                }
            }
        }
        Command::Rm { lfn } => {
            let ws = Workspace::open(root)?;
            ws.shim().rm(lfn)?;
            println!("removed {lfn}");
            ws.save()
        }
        Command::Verify { lfn } => {
            let ws = Workspace::open(root)?;
            let (ok, bad) = verify_chunks(&ws, lfn)?;
            println!("{lfn}: {ok} chunks OK, {bad} corrupt/missing");
            if bad > 0 {
                return Err(Error::Integrity {
                    path: lfn.clone(),
                    detail: format!("{bad} chunks failed checksum audit"),
                });
            }
            Ok(())
        }
        Command::Read { lfn, offset, len } => {
            let ws = Workspace::open(root)?;
            let mut reader = ws.shim().open_reader(lfn)?;
            let bytes = reader.read(*offset, *len)?;
            let stats = reader.stats();
            eprintln!(
                "read {} bytes via {} ranged GETs ({} fetched, {} segments decoded, \
                 {} cache hits)",
                bytes.len(),
                stats.range_gets,
                fmt_bytes(stats.bytes_fetched),
                stats.segments_decoded,
                stats.cache_hits
            );
            use std::io::Write;
            std::io::stdout().write_all(&bytes)?;
            Ok(())
        }
        Command::Meta { lfn } => {
            let ws = Workspace::open(root)?;
            for (k, v) in ws.dfc.meta(lfn)? {
                println!("{k} = {}", v.to_json());
            }
            Ok(())
        }
        Command::CatalogCompact { budget_mb } => {
            let ws = Workspace::open(root)?;
            let budget = budget_mb.map_or(u64::MAX, |mb| mb.saturating_mul(1_000_000));
            let t0 = std::time::Instant::now();
            let report = ws.dfc.compact_journal(budget)?;
            println!(
                "compacted: {} shard checkpoint(s), {} sealed segment(s) removed ({}) in {}",
                report.checkpoints,
                report.segments_removed,
                fmt_bytes(report.bytes_removed),
                fmt_secs(t0.elapsed().as_secs_f64())
            );
            Ok(())
        }
        Command::CatalogStats => {
            let ws = Workspace::open(root)?;
            let stats = ws.dfc.journal_stats()?;
            let (dirs, files) = ws.dfc.counts();
            println!(
                "catalogue: {dirs} dir(s), {files} file(s) over {} journaled shard(s)",
                stats.len()
            );
            let (mut live, mut garbage) = (0u64, 0u64);
            for (i, s) in stats.iter().enumerate() {
                let ckpt = s
                    .last_checkpoint_seg
                    .map_or("none".to_string(), |n| format!("seg-{n}"));
                println!(
                    "  shard {i}: {} segment(s), live {}, garbage {}, last checkpoint {}, {} op(s) since",
                    s.segments,
                    fmt_bytes(s.live_bytes),
                    fmt_bytes(s.garbage_bytes),
                    ckpt,
                    s.ops_since_checkpoint
                );
                live += s.live_bytes;
                garbage += s.garbage_bytes;
            }
            println!(
                "total: live {}, garbage {} (run `drs catalog compact` to reclaim)",
                fmt_bytes(live),
                fmt_bytes(garbage)
            );
            Ok(())
        }
        Command::SeList => {
            let ws = Workspace::open(root)?;
            println!("{} SEs, availability {:.0}%", ws.registry.len(), ws.registry.availability() * 100.0);
            for se in ws.registry.all() {
                println!(
                    "  {} [{}] {} {}",
                    se.name(),
                    se.region(),
                    fmt_bytes(se.used_bytes()),
                    if se.is_available() { "up" } else { "DOWN" }
                );
            }
            Ok(())
        }
        Command::SeKill { name } => {
            let ws = Workspace::open(root)?;
            let se = ws
                .registry
                .get(name)
                .ok_or_else(|| Error::Config(format!("no SE named `{name}`")))?;
            se.set_available(false);
            println!("{name} marked unavailable");
            ws.save()
        }
        Command::SeRevive { name } => {
            let ws = Workspace::open(root)?;
            let se = ws
                .registry
                .get(name)
                .ok_or_else(|| Error::Config(format!("no SE named `{name}`")))?;
            se.set_available(true);
            println!("{name} back online");
            ws.save()
        }
        Command::Lint { json, update_baseline, rules, root: lint_root } => {
            let rules = match rules {
                None => None,
                Some(list) => {
                    let mut parsed = Vec::new();
                    for item in list.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                        parsed.push(crate::analysis::Rule::from_arg(item)?);
                    }
                    Some(parsed)
                }
            };
            crate::analysis::run(&crate::analysis::LintOptions {
                json: *json,
                update_baseline: *update_baseline,
                rules,
                root: lint_root.clone(),
            })
        }
        Command::Durability { p } => {
            println!("file availability at SE availability p = {p}");
            println!("{:<18} {:>9} {:>14} {:>7}", "scheme", "overhead", "availability", "nines");
            for row in durability::comparison_table(*p) {
                println!(
                    "{:<18} {:>8.2}x {:>14.8} {:>7.2}",
                    row.scheme, row.overhead, row.availability, row.nines
                );
            }
            Ok(())
        }
        Command::Info => {
            println!("drs {} — three-layer rust+jax+pallas EC storage", env!("CARGO_PKG_VERSION"));
            let dir = crate::runtime::default_artifact_dir();
            println!("artifact dir: {}", dir.display());
            match crate::runtime::PjrtEngine::new(&dir) {
                Ok(engine) => {
                    let keys = engine.keys();
                    println!("PJRT CPU client OK; {} artifacts:", keys.len());
                    for k in keys {
                        println!("  {k:?}");
                    }
                }
                Err(e) => println!("PJRT unavailable ({e}); pure-rust fallback active"),
            }
            Ok(())
        }
    }
}
