//! # DRS — Distributed Resilient Storage
//!
//! A production-grade reproduction of *"Extending DIRAC File Management
//! with Erasure-Coding for efficient storage"* (Skipsey et al., CHEP2015,
//! J. Phys.: Conf. Ser. 664 042051).
//!
//! The crate implements the paper's erasure-coding shim over a DIRAC-style
//! file catalogue, plus every substrate it depends on:
//!
//! * [`gf`] — GF(2⁸) arithmetic and matrix algebra (the zfec field, poly
//!   `0x11D`).
//! * [`ec`] — the Reed–Solomon codec: striping, systematic Cauchy code,
//!   zfec-style chunk container, pluggable compute backends (pure rust or
//!   the AOT-compiled Pallas/XLA kernel via [`runtime`]).
//! * [`catalog`] — the DIRAC File Catalogue (DFC) substrate: hierarchical
//!   namespace, replica catalog, key-value metadata (with the paper's
//!   `SPLIT`/`TOTAL` convention and §4 prefix hygiene). Served
//!   concurrently by [`catalog::ShardedDfc`]: the namespace
//!   hash-partitioned over independently locked shards with
//!   directory-subtree affinity, plus lock-free snapshot scans
//!   (`snapshot_subtree`) so maintenance walks never block clients.
//! * [`se`] — Storage Elements: a trait with local-directory and
//!   simulated-network backends, availability/failure injection, registry.
//! * [`placement`] — chunk→SE placement policies (round-robin per the
//!   paper, plus random / weighted / region-aware).
//! * [`transfer`] — the §2.4 work-pool: bounded worker threads, retries,
//!   early termination once K chunks have arrived.
//! * [`dfm`] — the paper's contribution: the EC file-management shim
//!   (`put`/`get`/`repair`) and the whole-file replication baseline.
//!   Its data plane is the block-streaming pipeline ([`dfm::stream`]):
//!   bounded-memory, pipelined encode/transfer/decode — `put`/`get` of
//!   larger-than-RAM files hold O(N · block) bytes, with encode of one
//!   block overlapping transfer of the previous.
//! * [`maintenance`] — the site-resilience engine over the shim:
//!   catalogue-wide scrub (per-file health + surviving margin),
//!   prioritized repair under a bandwidth/concurrency budget, SE
//!   drain/rebalance for decommissioning, and the `drs maintain`
//!   daemon ([`maintenance::daemon`]) that runs the whole loop
//!   unattended on a cadence.
//! * [`sim`] — deterministic discrete-event simulator calibrated to the
//!   paper's Table 1 (setup latency + shared uplink), used by the
//!   figure-regeneration benches; Monte-Carlo durability analysis.
//! * [`cache`] — the byte-bounded, lock-sharded read cache under the
//!   get path: a decoded-block LRU with frequency-aware admission plus
//!   a degraded-read rebuilt-chunk cache that repair can adopt from,
//!   invalidated by the catalogue mutation path.
//! * [`obs`] — observability: structured span tracing over the whole
//!   data plane (near-zero cost when disabled), a JSONL trace sink,
//!   a Prometheus-format exporter for [`metrics`], and the embeddable
//!   HTTP status endpoint (`/status`, `/metrics`, `/traces/recent`).
//! * [`runtime`] — PJRT loader for the `artifacts/*.hlo.txt` produced by
//!   the python build path (L1 pallas kernel + L2 jax graph).
//!
//! Python never runs at request time: `make artifacts` lowers the jax/pallas
//! compute graph to HLO text once, and the rust binary loads it via PJRT.
//!
//! ## Quickstart
//!
//! ```no_run
//! use drs::prelude::*;
//!
//! let cluster = TestCluster::builder()
//!     .ses(5)
//!     .ec(EcParams::new(4, 2).unwrap())
//!     .build()
//!     .unwrap();
//! let data = vec![42u8; 1 << 20];
//! cluster.shim().put_bytes("/vo/user/demo.bin", &data, &PutOptions::default()).unwrap();
//! let back = cluster.shim().get_bytes("/vo/user/demo.bin", &GetOptions::default()).unwrap();
//! assert_eq!(back, data);
//! ```
//!
//! ## Further reading
//!
//! * `docs/ARCHITECTURE.md` — module map, the life of a file
//!   (upload → scrub → repair → drain), and where the sharded catalogue
//!   and its snapshot scans sit.
//! * `docs/OPERATIONS.md` — operator runbook for `drs scrub`,
//!   `drs repair-all` and `drs drain` (flags, budgets, health reports,
//!   incremental-scrub cursors).

#![warn(missing_docs)]

pub mod analysis;
pub mod cache;
pub mod catalog;
pub mod cli;
pub mod config;
pub mod dfm;
pub mod ec;
pub mod federation;
pub mod gf;
pub mod maintenance;
pub mod metrics;
pub mod obs;
pub mod placement;
pub mod runtime;
pub mod se;
pub mod sim;
pub mod testkit;
pub mod transfer;
pub mod util;

/// Convenient re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::catalog::{Dfc, MetaValue, ShardedDfc};
    pub use crate::config::Config;
    pub use crate::dfm::{
        EcShim, GetOptions, PutOptions, ReplicationManager, TestCluster,
    };
    pub use crate::ec::{BackendChoice, Codec, EcParams, PureRustBackend};
    pub use crate::placement::{PlacementPolicy, RoundRobin};
    pub use crate::se::{NetworkProfile, SeRegistry, StorageElement};
    pub use crate::sim::durability;
    pub use crate::transfer::PoolConfig;
}

/// Crate-wide error type (hand-rolled: `thiserror` is unavailable offline).
#[derive(Debug)]
#[allow(missing_docs)] // variant names + Display impls are the documentation
pub enum Error {
    Ec(String),
    Catalog(String),
    Se { se: String, msg: String },
    /// The SE's availability flag is down — distinct from backend I/O
    /// errors so mid-transfer outages surface cleanly per chunk.
    SeDown { se: String },
    Transfer(String),
    NotEnoughChunks { have: usize, need: usize },
    Integrity { path: String, detail: String },
    Runtime(String),
    Config(String),
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Ec(msg) => write!(f, "erasure-coding error: {msg}"),
            Error::Catalog(msg) => write!(f, "catalog error: {msg}"),
            Error::Se { se, msg } => write!(f, "storage element `{se}` error: {msg}"),
            Error::SeDown { se } => write!(f, "storage element `{se}` unavailable"),
            Error::Transfer(msg) => write!(f, "transfer failed: {msg}"),
            Error::NotEnoughChunks { have, need } => {
                write!(f, "not enough chunks: have {have}, need {need}")
            }
            Error::Integrity { path, detail } => {
                write!(f, "integrity check failed for {path}: {detail}")
            }
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
