//! # DRS — Distributed Resilient Storage
//!
//! A production-grade reproduction of *"Extending DIRAC File Management
//! with Erasure-Coding for efficient storage"* (Skipsey et al., CHEP2015,
//! J. Phys.: Conf. Ser. 664 042051).
//!
//! The crate implements the paper's erasure-coding shim over a DIRAC-style
//! file catalogue, plus every substrate it depends on:
//!
//! * [`gf`] — GF(2⁸) arithmetic and matrix algebra (the zfec field, poly
//!   `0x11D`).
//! * [`ec`] — the Reed–Solomon codec: striping, systematic Cauchy code,
//!   zfec-style chunk container, pluggable compute backends (pure rust or
//!   the AOT-compiled Pallas/XLA kernel via [`runtime`]).
//! * [`catalog`] — the DIRAC File Catalogue (DFC) substrate: hierarchical
//!   namespace, replica catalog, key-value metadata (with the paper's
//!   `SPLIT`/`TOTAL` convention and §4 prefix hygiene).
//! * [`se`] — Storage Elements: a trait with local-directory and
//!   simulated-network backends, availability/failure injection, registry.
//! * [`placement`] — chunk→SE placement policies (round-robin per the
//!   paper, plus random / weighted / region-aware).
//! * [`transfer`] — the §2.4 work-pool: bounded worker threads, retries,
//!   early termination once K chunks have arrived.
//! * [`dfm`] — the paper's contribution: the EC file-management shim
//!   (`put`/`get`/`repair`) and the whole-file replication baseline.
//! * [`sim`] — deterministic discrete-event simulator calibrated to the
//!   paper's Table 1 (setup latency + shared uplink), used by the
//!   figure-regeneration benches; Monte-Carlo durability analysis.
//! * [`runtime`] — PJRT loader for the `artifacts/*.hlo.txt` produced by
//!   the python build path (L1 pallas kernel + L2 jax graph).
//!
//! Python never runs at request time: `make artifacts` lowers the jax/pallas
//! compute graph to HLO text once, and the rust binary loads it via PJRT.
//!
//! ## Quickstart
//!
//! ```no_run
//! use drs::prelude::*;
//!
//! let cluster = TestCluster::builder()
//!     .ses(5)
//!     .ec(EcParams::new(4, 2).unwrap())
//!     .build()
//!     .unwrap();
//! let data = vec![42u8; 1 << 20];
//! cluster.shim().put_bytes("/vo/user/demo.bin", &data, &PutOptions::default()).unwrap();
//! let back = cluster.shim().get_bytes("/vo/user/demo.bin", &GetOptions::default()).unwrap();
//! assert_eq!(back, data);
//! ```

pub mod catalog;
pub mod cli;
pub mod config;
pub mod dfm;
pub mod ec;
pub mod federation;
pub mod gf;
pub mod metrics;
pub mod placement;
pub mod runtime;
pub mod se;
pub mod sim;
pub mod testkit;
pub mod transfer;
pub mod util;

/// Convenient re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::catalog::{Dfc, MetaValue};
    pub use crate::config::Config;
    pub use crate::dfm::{
        EcShim, GetOptions, PutOptions, ReplicationManager, TestCluster,
    };
    pub use crate::ec::{Codec, EcParams, PureRustBackend};
    pub use crate::placement::{PlacementPolicy, RoundRobin};
    pub use crate::se::{NetworkProfile, SeRegistry, StorageElement};
    pub use crate::sim::durability;
    pub use crate::transfer::PoolConfig;
}

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("erasure-coding error: {0}")]
    Ec(String),
    #[error("catalog error: {0}")]
    Catalog(String),
    #[error("storage element `{se}` error: {msg}")]
    Se { se: String, msg: String },
    #[error("transfer failed: {0}")]
    Transfer(String),
    #[error("not enough chunks: have {have}, need {need}")]
    NotEnoughChunks { have: usize, need: usize },
    #[error("integrity check failed for {path}: {detail}")]
    Integrity { path: String, detail: String },
    #[error("runtime error: {0}")]
    Runtime(String),
    #[error("config error: {0}")]
    Config(String),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

pub type Result<T> = std::result::Result<T, Error>;
