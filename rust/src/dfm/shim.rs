//! `EcShim`: put / get / repair / rm over erasure-coded files.
//!
//! Persistence note: the shim never saves the catalogue itself. Every
//! mutation it performs (`mkdir_p`/`set_meta` for the layout directory,
//! `add_file`/`register_replica` per chunk, replica swaps during
//! repair, `remove_dir` on `rm`) is lowered by [`ShardedDfc`] to a
//! typed [`crate::catalog::CatalogOp`] and appended to the owning
//! shard's write-ahead journal at the moment it happens — an upload
//! costs O(chunks) journal records, not an O(namespace) snapshot
//! rewrite after the command.

use std::collections::BTreeSet;
use std::path::Path;
use std::sync::Arc;

use crate::cache::ReadCache;
use crate::catalog::{MetaKeyStyle, MetaValue, ShardedDfc};
use crate::ec::chunk::HEADER_LEN;
use crate::ec::stripe::{chunk_payload_len, segment_count};
use crate::ec::{chunk_name, ChunkHeader, Codec, EcBackend, EcParams, PureRustBackend};
use crate::obs::{tracer, SpanRef};
use crate::placement::PlacementPolicy;
use crate::se::{SeInfo, SeRegistry, StorageElement};
use crate::{Error, Result};

use super::options::{GetOptions, PutOptions};
use super::stream::{
    self, BlockSource, FetchChunk, FileSource, Gauge, PipeCfg, RebuildTarget, SliceSource,
    StreamStats, UploadOutcome, UploadTarget,
};

/// Shim format version written to catalog metadata.
pub const SHIM_VERSION: i64 = 2;

/// Status of one erasure-coded file, as reported by [`EcShim::stat`].
#[derive(Clone, Debug)]
pub struct EcFileStat {
    /// The file's logical path (its chunk directory).
    pub lfn: String,
    /// Coding geometry (K data + M coding chunks).
    pub params: EcParams,
    /// Stripe width in bytes.
    pub stripe_b: usize,
    /// Per-chunk status, in chunk-index order.
    pub chunks: Vec<ChunkStat>,
    /// Chunks currently fetchable (replica SE up and object present).
    pub available_chunks: usize,
}

/// Status of one chunk within an [`EcFileStat`].
#[derive(Clone, Debug)]
pub struct ChunkStat {
    /// Chunk file name (`<base>.<i>_of_<n>.drs`).
    pub name: String,
    /// Chunk index within the code word.
    pub index: usize,
    /// The SE the catalogue points at (last replica probed).
    pub se: String,
    /// Whether the chunk is currently fetchable.
    pub available: bool,
}

impl EcFileStat {
    /// Whether the file can still be reconstructed.
    pub fn readable(&self) -> bool {
        self.available_chunks >= self.params.k()
    }

    /// Chunks lost relative to full health.
    pub fn degraded_by(&self) -> usize {
        self.chunks.len() - self.available_chunks
    }
}

/// The erasure-coding DFC shim (the paper's system).
pub struct EcShim {
    dfc: Arc<ShardedDfc>,
    registry: Arc<SeRegistry>,
    policy: Arc<dyn PlacementPolicy>,
    backend: Arc<dyn EcBackend>,
    vo: String,
    cache: Arc<ReadCache>,
}

impl EcShim {
    /// Wire a shim over a catalogue, SE registry, placement policy and
    /// coding backend for one VO. The read cache is disabled; use
    /// [`EcShim::with_cache`] to enable it.
    pub fn new(
        dfc: Arc<ShardedDfc>,
        registry: Arc<SeRegistry>,
        policy: Arc<dyn PlacementPolicy>,
        backend: Arc<dyn EcBackend>,
        vo: impl Into<String>,
    ) -> Self {
        Self::with_cache(dfc, registry, policy, backend, vo, Arc::new(ReadCache::disabled()))
    }

    /// [`EcShim::new`] with a shared [`ReadCache`] under the get path:
    /// downloads serve and populate the decoded-block pool, degraded
    /// gets retain rebuilt chunks, repair adopts them, and `rm`
    /// invalidates.
    pub fn with_cache(
        dfc: Arc<ShardedDfc>,
        registry: Arc<SeRegistry>,
        policy: Arc<dyn PlacementPolicy>,
        backend: Arc<dyn EcBackend>,
        vo: impl Into<String>,
        cache: Arc<ReadCache>,
    ) -> Self {
        EcShim { dfc, registry, policy, backend, vo: vo.into(), cache }
    }

    /// Convenience constructor with the paper's round-robin policy and the
    /// pure-rust backend.
    pub fn with_defaults(
        dfc: Arc<ShardedDfc>,
        registry: Arc<SeRegistry>,
        vo: impl Into<String>,
    ) -> Self {
        Self::new(
            dfc,
            registry,
            Arc::new(crate::placement::RoundRobin),
            Arc::new(PureRustBackend),
            vo,
        )
    }

    /// The sharded catalogue this shim operates on.
    pub fn dfc(&self) -> Arc<ShardedDfc> {
        Arc::clone(&self.dfc)
    }

    /// The SE registry this shim places chunks over.
    pub fn registry(&self) -> Arc<SeRegistry> {
        Arc::clone(&self.registry)
    }

    /// The placement policy (the maintenance engine re-places chunks
    /// through the same policy the shim placed them with).
    pub fn policy(&self) -> Arc<dyn PlacementPolicy> {
        Arc::clone(&self.policy)
    }

    /// The VO whose SE vector this shim places over.
    pub fn vo(&self) -> &str {
        &self.vo
    }

    /// The read cache the get path serves from (disabled unless the
    /// shim was built with [`EcShim::with_cache`]).
    pub fn cache(&self) -> Arc<ReadCache> {
        Arc::clone(&self.cache)
    }

    fn base_name(lfn: &str) -> Result<String> {
        lfn.rsplit('/')
            .next()
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .ok_or_else(|| Error::Catalog(format!("bad lfn `{lfn}`")))
    }

    // ------------------------------------------------------------------
    // put
    // ------------------------------------------------------------------

    /// Upload `data` as an erasure-coded file at `lfn`.
    ///
    /// Creates DFC directory `lfn` containing one DFC file per chunk,
    /// tagged with the paper's metadata; chunks are placed over the VO's
    /// SE vector by the configured policy and streamed through the block
    /// pipeline (encode of block *b+1* overlaps transfer of block *b*).
    /// Returns the SE name chosen for each chunk.
    pub fn put_bytes(&self, lfn: &str, data: &[u8], opts: &PutOptions) -> Result<Vec<String>> {
        let digest = crate::util::sha256::digest(data);
        let mut source = SliceSource::new(data);
        self.put_stream(lfn, &mut source, digest, opts).map(|(placed, _)| placed)
    }

    /// Upload the local file at `local` as an erasure-coded file at
    /// `lfn`, without ever materializing it: one streaming hash pre-pass
    /// (the headers carry the whole-file digest and are written first),
    /// then the block pipeline. Peak memory is O(N · block), so files
    /// larger than RAM upload fine.
    pub fn put_file(&self, lfn: &str, local: &Path, opts: &PutOptions) -> Result<Vec<String>> {
        self.put_file_stats(lfn, local, opts).map(|(placed, _)| placed)
    }

    /// [`EcShim::put_file`], additionally returning the pipeline's
    /// [`StreamStats`] (blocks, stalls, peak resident bytes, overlap).
    pub fn put_file_stats(
        &self,
        lfn: &str,
        local: &Path,
        opts: &PutOptions,
    ) -> Result<(Vec<String>, StreamStats)> {
        let mut source = FileSource::open(local)?;
        let digest = stream::hash_source(&mut source, opts.block_bytes)?;
        self.put_stream(lfn, &mut source, digest, opts)
    }

    /// The shared upload pipeline behind [`EcShim::put_bytes`] and
    /// [`EcShim::put_file`]: opens the transfer's root `put` trace span
    /// (every pipeline-stage span nests under it), then runs the steps.
    fn put_stream(
        &self,
        lfn: &str,
        source: &mut dyn BlockSource,
        digest: [u8; 32],
        opts: &PutOptions,
    ) -> Result<(Vec<String>, StreamStats)> {
        let root = tracer().span_with(SpanRef::NONE, "put", || {
            format!("{lfn} backend={}", self.backend.name())
        });
        let trace = root.handle();
        let res = self.put_stream_steps(lfn, source, digest, opts, trace);
        root.finish(res).map(|(names, mut stats)| {
            stats.trace_id = trace.trace;
            (names, stats)
        })
    }

    fn put_stream_steps(
        &self,
        lfn: &str,
        source: &mut dyn BlockSource,
        digest: [u8; 32],
        opts: &PutOptions,
        parent: SpanRef,
    ) -> Result<(Vec<String>, StreamStats)> {
        let infos = self.registry.vo_infos(&self.vo);
        if infos.is_empty() {
            return Err(Error::Config(format!("no SEs support VO `{}`", self.vo)));
        }
        if self.dfc.exists(lfn) {
            return Err(Error::Catalog(format!("`{lfn}` already exists")));
        }
        let base = Self::base_name(lfn)?;
        let codec = Codec::with_backend(opts.params, opts.stripe_b, Arc::clone(&self.backend))?;
        let n = opts.params.n();
        let file_len = source.total_len();
        let assignment = self.policy.place(n, &infos)?;

        // Register the chunk directory + the paper's metadata keys. The
        // directory (and with it every chunk file below) lives in one
        // catalogue shard, so concurrent uploads of different files do
        // not contend.
        self.dfc.mkdir_p(lfn)?;
        let gauge = Gauge::default();
        let mut placed: Vec<Option<UploadOutcome>> = (0..n).map(|_| None).collect();
        let result = self.put_stream_body(
            lfn, &base, source, &codec, file_len, digest, assignment, opts, &gauge,
            &mut placed, parent,
        );
        match result {
            Ok(()) => {
                let stats = gauge.snapshot();
                stream::record_stream_metrics(&stats);
                let names = placed
                    .into_iter()
                    .map(|o| o.expect("every chunk placed on success").se_name)
                    .collect();
                Ok((names, stats))
            }
            Err(e) => {
                // Failure unwinding: any error after `mkdir_p` — metadata
                // write, upload, or catalogue registration — deletes the
                // chunks that landed and removes the directory, so a
                // failed put never leaves a ghost catalogue entry. The
                // removals are lowered to journaled compensating ops by
                // the sharded catalogue.
                self.unwind_put(lfn, &placed);
                Err(e)
            }
        }
    }

    /// Everything a put does after `mkdir_p`: metadata, upload passes,
    /// catalogue registration. Split out so `put_stream` can unwind the
    /// directory on *any* error this returns.
    #[allow(clippy::too_many_arguments)]
    fn put_stream_body(
        &self,
        lfn: &str,
        base: &str,
        source: &mut dyn BlockSource,
        codec: &Codec,
        file_len: u64,
        digest: [u8; 32],
        assignment: Vec<usize>,
        opts: &PutOptions,
        gauge: &Gauge,
        placed: &mut [Option<UploadOutcome>],
        parent: SpanRef,
    ) -> Result<()> {
        let n = placed.len();
        let style = opts.key_style;
        self.dfc.set_meta(lfn, style.total_key(), MetaValue::Int(n as i64))?;
        self.dfc.set_meta(lfn, style.split_key(), MetaValue::Int(opts.params.k() as i64))?;
        self.dfc.set_meta(lfn, style.version_key(), MetaValue::Int(SHIM_VERSION))?;
        self.dfc.set_meta(lfn, style.stripe_key(), MetaValue::Int(opts.stripe_b as i64))?;
        self.run_upload_passes(
            lfn, base, source, codec, file_len, digest, assignment, opts, gauge, placed,
            parent,
        )?;
        // Register chunk files + replicas, in chunk-index order.
        for o in placed.iter().flatten() {
            let entry = crate::catalog::FileEntry {
                size: o.size,
                checksum: o.checksum_hex.clone(),
                replicas: vec![],
                meta: Default::default(),
            };
            self.dfc.add_file(&o.pfn, entry)?;
            self.dfc.register_replica(&o.pfn, &o.se_name, &o.pfn)?;
        }
        Ok(())
    }

    /// Streamed upload passes: pass 1 targets the policy's assignment;
    /// chunks that fail are retried (same SE, or the policy's fallback)
    /// in follow-up passes that re-stream the source and re-encode only
    /// the failed subset. SE availability is re-checked inside each
    /// transfer job, so a mid-upload outage fails that chunk with a
    /// clean [`Error::SeDown`] rather than a backend I/O error.
    #[allow(clippy::too_many_arguments)]
    fn run_upload_passes(
        &self,
        lfn: &str,
        base: &str,
        source: &mut dyn BlockSource,
        codec: &Codec,
        file_len: u64,
        digest: [u8; 32],
        assignment: Vec<usize>,
        opts: &PutOptions,
        gauge: &Gauge,
        placed: &mut [Option<UploadOutcome>],
        parent: SpanRef,
    ) -> Result<()> {
        let infos = self.registry.vo_infos(&self.vo);
        let ses = self.registry.vo_vector(&self.vo);
        let n = placed.len();
        let cfg =
            PipeCfg { workers: opts.workers.max(1), block_bytes: opts.block_bytes, parent };
        let mut current = assignment;
        let mut tried: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut pass = 0usize;
        loop {
            pass += 1;
            let targets: Vec<UploadTarget> = (0..n)
                .filter(|i| placed[*i].is_none())
                .map(|i| UploadTarget {
                    index: i,
                    se: Arc::clone(&ses[current[i]]),
                    pfn: format!("{lfn}/{}", chunk_name(base, i, n)),
                })
                .collect();
            if targets.is_empty() {
                return Ok(());
            }
            let (successes, failures) =
                stream::upload_pass(source, codec, file_len, digest, &targets, &cfg, gauge)?;
            for o in successes {
                placed[o.index] = Some(o);
            }
            if failures.is_empty() {
                return Ok(());
            }
            for (idx, _) in &failures {
                tried[*idx].push(current[*idx]);
            }
            if !opts.retry.retries_left(pass) {
                // The paper's semantics: any failed chunk fails the
                // upload (the caller unwinds what landed).
                let (idx, err) = &failures[0];
                return Err(Error::Transfer(format!(
                    "upload of chunk {idx} failed ({err}); put aborted per paper semantics"
                )));
            }
            if opts.retry.fallback_se {
                for (idx, err) in &failures {
                    match self.policy.fallback(*idx, &infos, &tried[*idx]) {
                        Some(next) => current[*idx] = next,
                        None => {
                            return Err(Error::Transfer(format!(
                                "upload of chunk {idx} failed ({err}); no fallback SE left"
                            )))
                        }
                    }
                }
            }
            // !fallback_se: retry the same SE (transient failures).
        }
    }

    /// Best-effort cleanup of a failed put: delete landed chunk objects,
    /// then remove the catalogue subtree (journaled compensating ops).
    ///
    /// Only reachable after this call's own `mkdir_p` — a put against an
    /// lfn that already exists is rejected before any mutation, so the
    /// unwind can never erase a previously committed file. Two *racing*
    /// puts of the same lfn have always been undefined (they write the
    /// same chunk pfns); the unwind does not change that.
    fn unwind_put(&self, lfn: &str, placed: &[Option<UploadOutcome>]) {
        for o in placed.iter().flatten() {
            if let Some(se) = self.registry.get(&o.se_name) {
                let _ = se.delete(&o.pfn);
            }
        }
        let _ = self.dfc.remove_dir(lfn);
    }

    // ------------------------------------------------------------------
    // get
    // ------------------------------------------------------------------

    /// Download and reconstruct the file at `lfn`.
    ///
    /// Streams block-by-block: the pipeline picks the first K chunks in
    /// index order (data chunks first, so a fully healthy file decodes
    /// on the identity path — the paper's early-stop optimisation),
    /// issues parallel same-offset block fetches across all K at once,
    /// and swaps a failed chunk for a spare mid-stream.
    pub fn get_bytes(&self, lfn: &str, opts: &GetOptions) -> Result<Vec<u8>> {
        let mut sink = stream::VecSink(Vec::new());
        self.get_into(lfn, &mut sink, opts)?;
        Ok(sink.0)
    }

    /// Download and reconstruct `lfn` straight into the local file at
    /// `local`, decoding block-by-block — peak memory is O(K · block),
    /// so files larger than RAM download fine.
    pub fn get_file(&self, lfn: &str, local: &Path, opts: &GetOptions) -> Result<u64> {
        self.get_file_stats(lfn, local, opts).map(|(bytes, _)| bytes)
    }

    /// [`EcShim::get_file`], additionally returning the pipeline's
    /// [`StreamStats`].
    pub fn get_file_stats(
        &self,
        lfn: &str,
        local: &Path,
        opts: &GetOptions,
    ) -> Result<(u64, StreamStats)> {
        // Stream into a uniquely named sibling temp file and rename only
        // on success, so a failed download (bad lfn, mid-stream SE
        // losses, digest mismatch) never clobbers a pre-existing
        // destination file — and concurrent gets to the same destination
        // never share a temp (last rename wins, each file whole).
        static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let tmp = {
            let name = local
                .file_name()
                .map(|n| n.to_string_lossy().into_owned())
                .unwrap_or_else(|| "out".into());
            let seq = TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            local.with_file_name(format!(
                "{name}.{}-{seq}.drs-part",
                std::process::id()
            ))
        };
        let mut sink = stream::FileSink::create(&tmp)?;
        match self.get_into_stats(lfn, &mut sink, opts) {
            Ok((bytes, stats)) => {
                sink.finish()?;
                std::fs::rename(&tmp, local)?;
                Ok((bytes, stats))
            }
            Err(e) => {
                drop(sink);
                let _ = std::fs::remove_file(&tmp);
                Err(e)
            }
        }
    }

    fn get_into(
        &self,
        lfn: &str,
        sink: &mut dyn stream::BlockSink,
        opts: &GetOptions,
    ) -> Result<u64> {
        self.get_into_stats(lfn, sink, opts).map(|(bytes, _)| bytes)
    }

    fn get_into_stats(
        &self,
        lfn: &str,
        sink: &mut dyn stream::BlockSink,
        opts: &GetOptions,
    ) -> Result<(u64, StreamStats)> {
        let root = tracer().span_with(SpanRef::NONE, "get", || {
            format!("{lfn} backend={}", self.backend.name())
        });
        let trace = root.handle();
        let res = self.get_into_steps(lfn, sink, opts, trace);
        root.finish(res).map(|(bytes, mut stats)| {
            stats.trace_id = trace.trace;
            (bytes, stats)
        })
    }

    fn get_into_steps(
        &self,
        lfn: &str,
        sink: &mut dyn stream::BlockSink,
        opts: &GetOptions,
        parent: SpanRef,
    ) -> Result<(u64, StreamStats)> {
        let (params, stripe_b, chunk_files) = self.read_layout(lfn)?;
        let codec = Codec::with_backend(params, stripe_b, Arc::clone(&self.backend))?;
        let candidates: Vec<FetchChunk> = chunk_files
            .into_iter()
            .map(|(index, _name, replicas)| FetchChunk { index, replicas })
            .collect();
        let cfg =
            PipeCfg { workers: opts.workers.max(1), block_bytes: opts.block_bytes, parent };
        let gauge = Gauge::default();
        let bytes = stream::download_pipeline(
            &self.registry,
            &codec,
            &candidates,
            sink,
            &cfg,
            opts.retry,
            &gauge,
            &self.cache,
            lfn,
        )?;
        let stats = gauge.snapshot();
        stream::record_stream_metrics(&stats);
        Ok((bytes, stats))
    }

    /// Parse the catalog layout of an EC file: params, stripe width and
    /// the chunk files with their replicas, ordered by chunk index.
    ///
    /// Reads from a point-in-time snapshot of the file's directory
    /// ([`ShardedDfc::snapshot_dir`] — one shard lock, one clone: the
    /// directory-affinity invariant puts the whole EC directory in its
    /// owner shard), so the layout is internally consistent and no
    /// catalogue lock is held while it is interpreted.
    fn read_layout(
        &self,
        lfn: &str,
    ) -> Result<(EcParams, usize, Vec<(usize, String, Vec<crate::catalog::Replica>)>)> {
        if !self.dfc.is_dir(lfn) {
            return Err(Error::Catalog(format!("`{lfn}` is not an EC file directory")));
        }
        let dfc = self.dfc.snapshot_dir(lfn)?;
        // Read TOTAL/SPLIT under either key style (V1 files remain readable).
        let meta_int = |key1: &str, key2: &str| -> Option<i64> {
            dfc.get_meta(lfn, key1)
                .ok()
                .flatten()
                .or_else(|| dfc.get_meta(lfn, key2).ok().flatten())
                .and_then(|v| v.as_int())
        };
        let style_v2 = MetaKeyStyle::V2Prefixed;
        let style_v1 = MetaKeyStyle::V1Generic;
        let total = meta_int(style_v2.total_key(), style_v1.total_key());
        let split = meta_int(style_v2.split_key(), style_v1.split_key());
        let stripe = meta_int(style_v2.stripe_key(), style_v1.stripe_key())
            .unwrap_or(crate::ec::DEFAULT_STRIPE_B as i64) as usize;

        // Collect chunk files; "as an additional check" (paper) the names
        // themselves carry (index, n) and must agree with the metadata.
        let mut chunk_files = Vec::new();
        for item in dfc.list_dir(lfn)? {
            if let crate::catalog::dfc::DirItem::File(name) = &item {
                if let Some((_base, index, n_from_name)) =
                    crate::ec::parse_chunk_name(name)
                {
                    let path = format!("{lfn}/{name}");
                    let replicas = dfc.replicas(&path)?.to_vec();
                    chunk_files.push((index, name.clone(), replicas, n_from_name));
                }
            }
        }
        if chunk_files.is_empty() {
            return Err(Error::Catalog(format!("`{lfn}` holds no chunk files")));
        }
        chunk_files.sort_by_key(|c| c.0);
        let n_from_names = chunk_files[0].3;

        let (k, n) = match (split, total) {
            (Some(s), Some(t)) => (s as usize, t as usize),
            // Fallback: derive from chunk names (metadata lost / V0 files).
            _ => {
                let n = n_from_names;
                // Without SPLIT we cannot know k; refuse rather than guess.
                return Err(Error::Catalog(format!(
                    "`{lfn}`: missing SPLIT/TOTAL metadata (names claim n={n})"
                )));
            }
        };
        if n != n_from_names {
            return Err(Error::Catalog(format!(
                "`{lfn}`: metadata TOTAL={n} disagrees with chunk names n={n_from_names}"
            )));
        }
        let params = EcParams::new(k, n - k)?;
        Ok((
            params,
            stripe,
            chunk_files.into_iter().map(|(i, name, r, _)| (i, name, r)).collect(),
        ))
    }

    /// Open a federated direct-IO reader over `lfn` (§4 future work:
    /// sparse reads without staging the whole file).
    pub fn open_reader(&self, lfn: &str) -> Result<crate::federation::EcFileReader> {
        let (params, stripe_b, chunk_files) = self.read_layout(lfn)?;
        let mut replicas = vec![Vec::new(); params.n()];
        for (index, _name, reps) in chunk_files {
            replicas[index] = reps;
        }
        let reader = crate::federation::EcFileReader::new(
            Arc::clone(&self.registry),
            Arc::clone(&self.backend),
            params,
            stripe_b,
            replicas,
        )?
        .with_cache(Arc::clone(&self.cache));
        self.cache.note_lfn(lfn, reader.digest());
        Ok(reader)
    }

    // ------------------------------------------------------------------
    // stat / repair / rm
    // ------------------------------------------------------------------

    /// Health report for an EC file.
    pub fn stat(&self, lfn: &str) -> Result<EcFileStat> {
        let (params, stripe_b, chunk_files) = self.read_layout(lfn)?;
        let mut chunks = Vec::new();
        let mut available = 0usize;
        for (index, name, replicas) in &chunk_files {
            let mut up = false;
            let mut se_name = String::new();
            for r in replicas {
                se_name = r.se.clone();
                if let Some(se) = self.registry.get(&r.se) {
                    if se.is_available() && se.exists(&r.pfn) {
                        up = true;
                        break;
                    }
                }
            }
            if up {
                available += 1;
            }
            chunks.push(ChunkStat { name: name.clone(), index: *index, se: se_name, available: up });
        }
        Ok(EcFileStat {
            lfn: lfn.to_string(),
            params,
            stripe_b,
            chunks,
            available_chunks: available,
        })
    }

    /// Re-derive lost chunks from survivors and place them on healthy SEs.
    ///
    /// Returns the number of chunks repaired. The catalog replica records
    /// are updated to point at the new locations.
    pub fn repair(&self, lfn: &str, opts: &GetOptions) -> Result<usize> {
        self.repair_excluding(lfn, opts, &[])
    }

    /// [`EcShim::repair`], but never placing rebuilt chunks on any SE in
    /// `excluded` — the maintenance drain uses this so a repair cannot
    /// re-populate the SE being evacuated.
    pub fn repair_excluding(
        &self,
        lfn: &str,
        opts: &GetOptions,
        excluded: &[String],
    ) -> Result<usize> {
        let root = tracer().span_with(SpanRef::NONE, "repair", || {
            format!("{lfn} backend={}", self.backend.name())
        });
        let parent = root.handle();
        root.finish(self.repair_excluding_steps(lfn, opts, excluded, parent))
    }

    fn repair_excluding_steps(
        &self,
        lfn: &str,
        opts: &GetOptions,
        excluded: &[String],
        parent: SpanRef,
    ) -> Result<usize> {
        let stat = self.stat(lfn)?;
        if !stat.readable() {
            return Err(Error::NotEnoughChunks {
                have: stat.available_chunks,
                need: stat.params.k(),
            });
        }
        let missing: Vec<usize> =
            stat.chunks.iter().filter(|c| !c.available).map(|c| c.index).collect();
        if missing.is_empty() {
            return Ok(0);
        }

        let (params, stripe_b, chunk_files) = self.read_layout(lfn)?;
        let codec = Codec::with_backend(params, stripe_b, Arc::clone(&self.backend))?;
        // Survivor candidates, in index order (data chunks first): the
        // rebuild pipeline streams K of them block-by-block, so repairing
        // one large file never spikes memory beyond O(K · block).
        let available: BTreeSet<usize> =
            stat.chunks.iter().filter(|c| c.available).map(|c| c.index).collect();
        let candidates: Vec<FetchChunk> = chunk_files
            .iter()
            .filter(|(i, _, _)| available.contains(i))
            .map(|(i, _, reps)| FetchChunk { index: *i, replicas: reps.clone() })
            .collect();

        // Place rebuilt chunks through the placement policy with sibling
        // anti-affinity, like the drain path: SEs already holding a live
        // chunk of this file — or chosen for an earlier rebuilt chunk of
        // this pass — are not eligible, so a multi-chunk repair cannot
        // stack several rebuilt chunks on one SE. When that leaves no
        // candidate (fewer SEs than chunks), relax to avoiding only this
        // pass's own placements; `excluded` is never relaxed.
        let infos = self.registry.vo_infos(&self.vo);
        let mut holding: BTreeSet<String> = stat
            .chunks
            .iter()
            .filter(|c| c.available)
            .map(|c| c.se.clone())
            .collect();
        let mut chosen: BTreeSet<String> = BTreeSet::new();
        let base = Self::base_name(lfn)?;
        let n = params.n();
        let mut placements: Vec<(usize, Arc<dyn StorageElement>, String)> = Vec::new();
        for (ordinal, &idx) in missing.iter().enumerate() {
            let eligible = |avoid: &BTreeSet<String>| -> Vec<SeInfo> {
                infos
                    .iter()
                    .filter(|s| {
                        s.available && !excluded.contains(&s.name) && !avoid.contains(&s.name)
                    })
                    .cloned()
                    .collect()
            };
            let mut eligible_ses = eligible(&holding);
            if eligible_ses.is_empty() {
                eligible_ses = eligible(&chosen);
            }
            if eligible_ses.is_empty() {
                return Err(Error::Transfer("no SE available for repair".into()));
            }
            // One placement slot per chunk; rotating the candidate list by
            // the rebuild ordinal spreads successive chunks across the
            // vector (round-robin stays round-robin) without asking the
            // policy for slots it will not use.
            eligible_ses.rotate_left(ordinal % eligible_ses.len());
            let slot = *self
                .policy
                .place(1, &eligible_ses)?
                .first()
                .ok_or_else(|| Error::Ec("placement returned no slot".into()))?;
            let target = eligible_ses
                .get(slot)
                .ok_or_else(|| Error::Ec("placement slot out of range".into()))?
                .name
                .clone();
            let se = self
                .registry
                .get(&target)
                .ok_or_else(|| Error::Config("registry inconsistent".into()))?;
            let pfn = format!("{lfn}/{}", chunk_name(&base, idx, n));
            holding.insert(target.clone());
            chosen.insert(target);
            placements.push((idx, se, pfn));
        }

        // Adoption first: a degraded get that already failed over will
        // have derived (and cached) the lost chunks' blocks; if the
        // degraded cache fully covers a chunk and the reassembled wire
        // bytes match the catalogue checksum, the chunk is written
        // straight from memory — no K-survivor re-stream at all.
        let cfg =
            PipeCfg { workers: opts.workers.max(1), block_bytes: opts.block_bytes, parent };
        let mut remaining: Vec<(usize, Arc<dyn StorageElement>, String)> = Vec::new();
        let mut adopted = 0usize;
        let adopt_hdr = if self.cache.degraded_enabled() {
            stream::probe_header(&self.registry, &codec, &candidates, opts.retry, parent).ok()
        } else {
            None
        };
        for (idx, se, pfn) in placements {
            let ok = match &adopt_hdr {
                Some(hdr) => {
                    self.try_adopt_chunk(hdr, &codec, opts, idx, &se, &pfn, parent)
                }
                None => false,
            };
            if ok {
                adopted += 1;
            } else {
                remaining.push((idx, se, pfn));
            }
        }
        if adopted > 0 {
            self.cache.note_adopted(adopted as u64);
        }

        if !remaining.is_empty() {
            // Stream: fetch K survivors once, re-derive every missing
            // chunk per block (`missing rows = R · survivor rows`),
            // committing the rebuilt sinks only after the whole-file
            // digest verifies. The rebuilt wire chunks are bit-identical
            // to the originals.
            let targets: Vec<RebuildTarget<'_>> = remaining
                .iter()
                .map(|(idx, se, pfn)| {
                    Ok(RebuildTarget { index: *idx, sink: se.put_writer(pfn)? })
                })
                .collect::<Result<_>>()?;
            let gauge = Gauge::default();
            stream::rebuild_pipeline(
                &self.registry,
                &codec,
                &candidates,
                targets,
                &cfg,
                opts.retry,
                &gauge,
            )?;
            stream::record_stream_metrics(&gauge.snapshot());
        }

        // Drop stale replica records, then register the new locations.
        for (_, se, pfn) in &remaining {
            let old: Vec<String> =
                self.dfc.replicas(pfn)?.iter().map(|r| r.se.clone()).collect();
            for se_name in old {
                let _ = self.dfc.remove_replica(pfn, &se_name);
            }
            self.dfc.register_replica(pfn, se.name(), pfn)?;
        }
        // Every repaired chunk is live again: its degraded-cache entries
        // are no longer needed (and would shadow nothing — the decoded
        // bytes are unchanged), so reclaim the space eagerly.
        if let Some(hdr) = &adopt_hdr {
            for &idx in &missing {
                self.cache.invalidate_chunk(&hdr.file_sha256, idx);
            }
        }
        Ok(adopted + remaining.len())
    }

    /// Try to materialize the lost chunk `idx` at `pfn` on `se` purely
    /// from the degraded-read cache: every payload block must be
    /// resident and the reassembled wire chunk must hash to the
    /// catalogue's recorded checksum. Returns `false` (falling back to
    /// the streaming rebuild) on any gap, mismatch or write failure.
    #[allow(clippy::too_many_arguments)]
    fn try_adopt_chunk(
        &self,
        hdr: &ChunkHeader,
        codec: &Codec,
        opts: &GetOptions,
        idx: usize,
        se: &Arc<dyn StorageElement>,
        pfn: &str,
        parent: SpanRef,
    ) -> bool {
        let params = codec.params();
        let (k, sb) = (params.k(), codec.stripe_b());
        let digest = hdr.file_sha256;
        let block_segs = (opts.block_bytes / (k * sb)).max(1) as u64;
        let row_block = block_segs * sb as u64;
        let segs = segment_count(hdr.file_len, k, sb);
        let n_blocks = segs.div_ceil(block_segs);
        let payload_len = chunk_payload_len(hdr.file_len, k, sb);
        let mut blocks = Vec::with_capacity(n_blocks as usize);
        for b in 0..n_blocks {
            match self.cache.get_chunk_block(&digest, idx, row_block, b) {
                Some(d) => blocks.push(d),
                None => return false,
            }
        }
        let header = ChunkHeader::new(params, idx, sb, hdr.file_len, payload_len, digest)
            .encode();
        let mut hasher = crate::util::sha256::Sha256::new();
        hasher.update(&header);
        let mut total = header.len() as u64;
        for d in &blocks {
            hasher.update(d);
            total += d.len() as u64;
        }
        if total != HEADER_LEN as u64 + payload_len {
            return false;
        }
        let expect = match self.dfc.file(pfn) {
            Ok(entry) => entry.checksum,
            Err(_) => return false,
        };
        if crate::util::hexfmt::encode(&hasher.finalize()) != expect {
            return false;
        }
        let mut sink = match se.put_writer(pfn) {
            Ok(s) => s,
            Err(_) => return false,
        };
        let write_all = (|| -> Result<()> {
            sink.write_block(&header)?;
            for d in &blocks {
                sink.write_block(d)?;
            }
            Ok(())
        })();
        let committed = match write_all {
            Ok(()) => sink.commit().is_ok(),
            Err(_) => {
                sink.abort();
                false
            }
        };
        if !committed {
            return false;
        }
        // Swap the replica record onto the adopting SE (same as the
        // streamed-rebuild path does after commit).
        let old: Vec<String> = match self.dfc.replicas(pfn) {
            Ok(r) => r.iter().map(|x| x.se.clone()).collect(),
            Err(_) => Vec::new(),
        };
        for se_name in old {
            let _ = self.dfc.remove_replica(pfn, &se_name);
        }
        if self.dfc.register_replica(pfn, se.name(), pfn).is_err() {
            return false;
        }
        tracer().event(parent, "cache", true, || {
            format!("adopted chunk {idx} from degraded cache ({total} B)")
        });
        true
    }

    /// Delete the EC file: best-effort removal of chunk objects, then the
    /// catalog subtree. Cached blocks for the path are dropped *before*
    /// the catalogue mutation, so no concurrent get can re-pin them
    /// against a path that is about to disappear.
    pub fn rm(&self, lfn: &str) -> Result<()> {
        let (_, _, chunk_files) = self.read_layout(lfn)?;
        self.cache.invalidate_lfn(lfn);
        for (_, _, replicas) in &chunk_files {
            for r in replicas {
                if let Some(se) = self.registry.get(&r.se) {
                    let _ = se.delete(&r.pfn);
                }
            }
        }
        self.dfc.remove_dir(lfn)
    }
}

