//! `EcShim`: put / get / repair / rm over erasure-coded files.
//!
//! Persistence note: the shim never saves the catalogue itself. Every
//! mutation it performs (`mkdir_p`/`set_meta` for the layout directory,
//! `add_file`/`register_replica` per chunk, replica swaps during
//! repair, `remove_dir` on `rm`) is lowered by [`ShardedDfc`] to a
//! typed [`crate::catalog::CatalogOp`] and appended to the owning
//! shard's write-ahead journal at the moment it happens — an upload
//! costs O(chunks) journal records, not an O(namespace) snapshot
//! rewrite after the command.

use std::collections::BTreeSet;
use std::sync::Arc;

use crate::catalog::{MetaKeyStyle, MetaValue, ShardedDfc};
use crate::ec::{chunk_name, Codec, EcBackend, EcParams, PureRustBackend};
use crate::placement::PlacementPolicy;
use crate::se::{SeInfo, SeRegistry, StorageElement};
use crate::transfer::{PoolConfig, RetryPolicy, WorkPool};
use crate::{Error, Result};

use super::options::{GetOptions, PutOptions};

/// Shim format version written to catalog metadata.
pub const SHIM_VERSION: i64 = 2;

/// Status of one erasure-coded file, as reported by [`EcShim::stat`].
#[derive(Clone, Debug)]
pub struct EcFileStat {
    /// The file's logical path (its chunk directory).
    pub lfn: String,
    /// Coding geometry (K data + M coding chunks).
    pub params: EcParams,
    /// Stripe width in bytes.
    pub stripe_b: usize,
    /// Per-chunk status, in chunk-index order.
    pub chunks: Vec<ChunkStat>,
    /// Chunks currently fetchable (replica SE up and object present).
    pub available_chunks: usize,
}

/// Status of one chunk within an [`EcFileStat`].
#[derive(Clone, Debug)]
pub struct ChunkStat {
    /// Chunk file name (`<base>.<i>_of_<n>.drs`).
    pub name: String,
    /// Chunk index within the code word.
    pub index: usize,
    /// The SE the catalogue points at (last replica probed).
    pub se: String,
    /// Whether the chunk is currently fetchable.
    pub available: bool,
}

impl EcFileStat {
    /// Whether the file can still be reconstructed.
    pub fn readable(&self) -> bool {
        self.available_chunks >= self.params.k()
    }

    /// Chunks lost relative to full health.
    pub fn degraded_by(&self) -> usize {
        self.chunks.len() - self.available_chunks
    }
}

/// The erasure-coding DFC shim (the paper's system).
pub struct EcShim {
    dfc: Arc<ShardedDfc>,
    registry: Arc<SeRegistry>,
    policy: Arc<dyn PlacementPolicy>,
    backend: Arc<dyn EcBackend>,
    vo: String,
}

impl EcShim {
    /// Wire a shim over a catalogue, SE registry, placement policy and
    /// coding backend for one VO.
    pub fn new(
        dfc: Arc<ShardedDfc>,
        registry: Arc<SeRegistry>,
        policy: Arc<dyn PlacementPolicy>,
        backend: Arc<dyn EcBackend>,
        vo: impl Into<String>,
    ) -> Self {
        EcShim { dfc, registry, policy, backend, vo: vo.into() }
    }

    /// Convenience constructor with the paper's round-robin policy and the
    /// pure-rust backend.
    pub fn with_defaults(
        dfc: Arc<ShardedDfc>,
        registry: Arc<SeRegistry>,
        vo: impl Into<String>,
    ) -> Self {
        Self::new(
            dfc,
            registry,
            Arc::new(crate::placement::RoundRobin),
            Arc::new(PureRustBackend),
            vo,
        )
    }

    /// The sharded catalogue this shim operates on.
    pub fn dfc(&self) -> Arc<ShardedDfc> {
        Arc::clone(&self.dfc)
    }

    /// The SE registry this shim places chunks over.
    pub fn registry(&self) -> Arc<SeRegistry> {
        Arc::clone(&self.registry)
    }

    /// The placement policy (the maintenance engine re-places chunks
    /// through the same policy the shim placed them with).
    pub fn policy(&self) -> Arc<dyn PlacementPolicy> {
        Arc::clone(&self.policy)
    }

    /// The VO whose SE vector this shim places over.
    pub fn vo(&self) -> &str {
        &self.vo
    }

    fn base_name(lfn: &str) -> Result<String> {
        lfn.rsplit('/')
            .next()
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .ok_or_else(|| Error::Catalog(format!("bad lfn `{lfn}`")))
    }

    // ------------------------------------------------------------------
    // put
    // ------------------------------------------------------------------

    /// Upload `data` as an erasure-coded file at `lfn`.
    ///
    /// Creates DFC directory `lfn` containing one DFC file per chunk,
    /// tagged with the paper's metadata; chunks are placed over the VO's
    /// SE vector by the configured policy and uploaded through the work
    /// pool. Returns the SE name chosen for each chunk.
    pub fn put_bytes(&self, lfn: &str, data: &[u8], opts: &PutOptions) -> Result<Vec<String>> {
        let infos = self.registry.vo_infos(&self.vo);
        if infos.is_empty() {
            return Err(Error::Config(format!("no SEs support VO `{}`", self.vo)));
        }
        if self.dfc.exists(lfn) {
            return Err(Error::Catalog(format!("`{lfn}` already exists")));
        }
        let base = Self::base_name(lfn)?;
        let codec = Codec::with_backend(opts.params, opts.stripe_b, Arc::clone(&self.backend))?;
        let chunks = codec.encode(data)?;
        let n = opts.params.n();
        let assignment = self.policy.place(n, &infos)?;

        // Register the chunk directory + the paper's metadata keys. The
        // directory (and with it every chunk file below) lives in one
        // catalogue shard, so concurrent uploads of different files do
        // not contend.
        self.dfc.mkdir_p(lfn)?;
        let style = opts.key_style;
        self.dfc.set_meta(lfn, style.total_key(), MetaValue::Int(n as i64))?;
        self.dfc.set_meta(lfn, style.split_key(), MetaValue::Int(opts.params.k() as i64))?;
        self.dfc.set_meta(lfn, style.version_key(), MetaValue::Int(SHIM_VERSION))?;
        self.dfc.set_meta(lfn, style.stripe_key(), MetaValue::Int(opts.stripe_b as i64))?;

        // Upload jobs: chunk i → SE assignment[i], with optional retry /
        // fallback to the next SE in the vector.
        let ses = self.registry.vo_vector(&self.vo);
        let mut jobs: Vec<(usize, Box<dyn FnOnce() -> Result<(usize, String, String, u64, String)> + Send>)> =
            Vec::with_capacity(n);
        for (i, wire) in chunks.into_iter().enumerate() {
            let name = chunk_name(&base, i, n);
            let pfn = format!("{lfn}/{name}");
            let primary = assignment[i];
            let ses = ses.clone();
            let infos = infos.clone();
            let policy = Arc::clone(&self.policy);
            let retry = opts.retry;
            jobs.push((
                i,
                Box::new(move || {
                    upload_with_retry(&ses, &infos, policy.as_ref(), retry, i, primary, &pfn, &wire)
                        .map(|se_name| {
                            let digest = crate::ec::chunk::sha256(&wire);
                            (i, se_name, pfn, wire.len() as u64, crate::util::hexfmt::encode(&digest))
                        })
                }),
            ));
        }

        let pool = WorkPool::new(PoolConfig::parallel(opts.workers));
        let outcome = pool.run(jobs, usize::MAX);

        if !outcome.failures.is_empty() {
            // The paper's semantics: any failed chunk fails the upload.
            // Clean up what landed, then remove the catalog entries.
            for (_, se_name, pfn, _, _) in outcome.successes.iter().map(|(_, v)| v) {
                if let Some(se) = self.registry.get(se_name) {
                    let _ = se.delete(pfn);
                }
            }
            let _ = self.dfc.remove_dir(lfn);
            let (idx, err) = &outcome.failures[0];
            return Err(Error::Transfer(format!(
                "upload of chunk {idx} failed ({err}); put aborted per paper semantics"
            )));
        }

        // Register chunk files + replicas.
        let mut per_chunk_se = vec![String::new(); n];
        let mut rows: Vec<&(usize, String, String, u64, String)> =
            outcome.successes.iter().map(|(_, v)| v).collect();
        rows.sort_by_key(|r| r.0);
        for (i, se_name, pfn, size, checksum) in rows {
            let name = chunk_name(&base, *i, n);
            let entry = crate::catalog::FileEntry {
                size: *size,
                checksum: checksum.clone(),
                replicas: vec![],
                meta: Default::default(),
            };
            self.dfc.add_file(&format!("{lfn}/{name}"), entry)?;
            self.dfc.register_replica(&format!("{lfn}/{name}"), se_name, pfn)?;
            per_chunk_se[*i] = se_name.clone();
        }
        Ok(per_chunk_se)
    }

    // ------------------------------------------------------------------
    // get
    // ------------------------------------------------------------------

    /// Download and reconstruct the file at `lfn`.
    ///
    /// Fetch jobs are queued in chunk order (data chunks first, so a fully
    /// healthy file decodes on the identity path) and the pool stops after
    /// K successes — the paper's early-stop optimisation.
    pub fn get_bytes(&self, lfn: &str, opts: &GetOptions) -> Result<Vec<u8>> {
        let (params, stripe_b, chunk_files) = self.read_layout(lfn)?;

        // Build fetch jobs.
        let mut jobs: Vec<(usize, Box<dyn FnOnce() -> Result<(usize, Vec<u8>)> + Send>)> =
            Vec::new();
        for (index, _name, replicas) in &chunk_files {
            let index = *index;
            let replicas = replicas.clone();
            let registry = Arc::clone(&self.registry);
            let retry = opts.retry;
            jobs.push((
                index,
                Box::new(move || fetch_with_retry(&registry, &replicas, retry, index)),
            ));
        }

        let pool = WorkPool::new(PoolConfig::parallel(opts.workers));
        let outcome = pool.run(jobs, params.k());
        if outcome.success_count() < params.k() {
            return Err(Error::NotEnoughChunks {
                have: outcome.success_count(),
                need: params.k(),
            });
        }

        let codec = Codec::with_backend(params, stripe_b, Arc::clone(&self.backend))?;
        let fetched: Vec<(usize, Vec<u8>)> =
            outcome.successes.into_iter().map(|(_, v)| v).collect();
        codec.decode(&fetched)
    }

    /// Parse the catalog layout of an EC file: params, stripe width and
    /// the chunk files with their replicas, ordered by chunk index.
    ///
    /// Reads from a point-in-time snapshot of the file's directory
    /// ([`ShardedDfc::snapshot_dir`] — one shard lock, one clone: the
    /// directory-affinity invariant puts the whole EC directory in its
    /// owner shard), so the layout is internally consistent and no
    /// catalogue lock is held while it is interpreted.
    fn read_layout(
        &self,
        lfn: &str,
    ) -> Result<(EcParams, usize, Vec<(usize, String, Vec<crate::catalog::Replica>)>)> {
        if !self.dfc.is_dir(lfn) {
            return Err(Error::Catalog(format!("`{lfn}` is not an EC file directory")));
        }
        let dfc = self.dfc.snapshot_dir(lfn)?;
        // Read TOTAL/SPLIT under either key style (V1 files remain readable).
        let meta_int = |key1: &str, key2: &str| -> Option<i64> {
            dfc.get_meta(lfn, key1)
                .ok()
                .flatten()
                .or_else(|| dfc.get_meta(lfn, key2).ok().flatten())
                .and_then(|v| v.as_int())
        };
        let style_v2 = MetaKeyStyle::V2Prefixed;
        let style_v1 = MetaKeyStyle::V1Generic;
        let total = meta_int(style_v2.total_key(), style_v1.total_key());
        let split = meta_int(style_v2.split_key(), style_v1.split_key());
        let stripe = meta_int(style_v2.stripe_key(), style_v1.stripe_key())
            .unwrap_or(crate::ec::DEFAULT_STRIPE_B as i64) as usize;

        // Collect chunk files; "as an additional check" (paper) the names
        // themselves carry (index, n) and must agree with the metadata.
        let mut chunk_files = Vec::new();
        for item in dfc.list_dir(lfn)? {
            if let crate::catalog::dfc::DirItem::File(name) = &item {
                if let Some((_base, index, n_from_name)) =
                    crate::ec::parse_chunk_name(name)
                {
                    let path = format!("{lfn}/{name}");
                    let replicas = dfc.replicas(&path)?.to_vec();
                    chunk_files.push((index, name.clone(), replicas, n_from_name));
                }
            }
        }
        if chunk_files.is_empty() {
            return Err(Error::Catalog(format!("`{lfn}` holds no chunk files")));
        }
        chunk_files.sort_by_key(|c| c.0);
        let n_from_names = chunk_files[0].3;

        let (k, n) = match (split, total) {
            (Some(s), Some(t)) => (s as usize, t as usize),
            // Fallback: derive from chunk names (metadata lost / V0 files).
            _ => {
                let n = n_from_names;
                // Without SPLIT we cannot know k; refuse rather than guess.
                return Err(Error::Catalog(format!(
                    "`{lfn}`: missing SPLIT/TOTAL metadata (names claim n={n})"
                )));
            }
        };
        if n != n_from_names {
            return Err(Error::Catalog(format!(
                "`{lfn}`: metadata TOTAL={n} disagrees with chunk names n={n_from_names}"
            )));
        }
        let params = EcParams::new(k, n - k)?;
        Ok((
            params,
            stripe,
            chunk_files.into_iter().map(|(i, name, r, _)| (i, name, r)).collect(),
        ))
    }

    /// Open a federated direct-IO reader over `lfn` (§4 future work:
    /// sparse reads without staging the whole file).
    pub fn open_reader(&self, lfn: &str) -> Result<crate::federation::EcFileReader> {
        let (params, stripe_b, chunk_files) = self.read_layout(lfn)?;
        let mut replicas = vec![Vec::new(); params.n()];
        for (index, _name, reps) in chunk_files {
            replicas[index] = reps;
        }
        crate::federation::EcFileReader::new(
            Arc::clone(&self.registry),
            Arc::clone(&self.backend),
            params,
            stripe_b,
            replicas,
        )
    }

    // ------------------------------------------------------------------
    // stat / repair / rm
    // ------------------------------------------------------------------

    /// Health report for an EC file.
    pub fn stat(&self, lfn: &str) -> Result<EcFileStat> {
        let (params, stripe_b, chunk_files) = self.read_layout(lfn)?;
        let mut chunks = Vec::new();
        let mut available = 0usize;
        for (index, name, replicas) in &chunk_files {
            let mut up = false;
            let mut se_name = String::new();
            for r in replicas {
                se_name = r.se.clone();
                if let Some(se) = self.registry.get(&r.se) {
                    if se.is_available() && se.exists(&r.pfn) {
                        up = true;
                        break;
                    }
                }
            }
            if up {
                available += 1;
            }
            chunks.push(ChunkStat { name: name.clone(), index: *index, se: se_name, available: up });
        }
        Ok(EcFileStat {
            lfn: lfn.to_string(),
            params,
            stripe_b,
            chunks,
            available_chunks: available,
        })
    }

    /// Re-derive lost chunks from survivors and place them on healthy SEs.
    ///
    /// Returns the number of chunks repaired. The catalog replica records
    /// are updated to point at the new locations.
    pub fn repair(&self, lfn: &str, opts: &GetOptions) -> Result<usize> {
        self.repair_excluding(lfn, opts, &[])
    }

    /// [`EcShim::repair`], but never placing rebuilt chunks on any SE in
    /// `excluded` — the maintenance drain uses this so a repair cannot
    /// re-populate the SE being evacuated.
    pub fn repair_excluding(
        &self,
        lfn: &str,
        opts: &GetOptions,
        excluded: &[String],
    ) -> Result<usize> {
        let stat = self.stat(lfn)?;
        if !stat.readable() {
            return Err(Error::NotEnoughChunks {
                have: stat.available_chunks,
                need: stat.params.k(),
            });
        }
        let missing: Vec<usize> =
            stat.chunks.iter().filter(|c| !c.available).map(|c| c.index).collect();
        if missing.is_empty() {
            return Ok(0);
        }

        let (params, stripe_b, chunk_files) = self.read_layout(lfn)?;
        // Fetch K surviving chunks (early-stop pool, like get).
        let mut jobs: Vec<(usize, Box<dyn FnOnce() -> Result<(usize, Vec<u8>)> + Send>)> =
            Vec::new();
        for (index, _name, replicas) in &chunk_files {
            if missing.contains(index) {
                continue;
            }
            let index = *index;
            let replicas = replicas.clone();
            let registry = Arc::clone(&self.registry);
            let retry = opts.retry;
            jobs.push((
                index,
                Box::new(move || fetch_with_retry(&registry, &replicas, retry, index)),
            ));
        }
        let outcome = WorkPool::new(PoolConfig::parallel(opts.workers)).run(jobs, params.k());
        if outcome.success_count() < params.k() {
            return Err(Error::NotEnoughChunks {
                have: outcome.success_count(),
                need: params.k(),
            });
        }
        let survivors: Vec<(usize, Vec<u8>)> =
            outcome.successes.into_iter().map(|(_, v)| v).collect();
        let codec = Codec::with_backend(params, stripe_b, Arc::clone(&self.backend))?;
        let rebuilt = codec.repair(&survivors, &missing)?;

        // Place rebuilt chunks through the placement policy with sibling
        // anti-affinity, like the drain path: SEs already holding a live
        // chunk of this file — or chosen for an earlier rebuilt chunk of
        // this pass — are not eligible, so a multi-chunk repair cannot
        // stack several rebuilt chunks on one SE. When that leaves no
        // candidate (fewer SEs than chunks), relax to avoiding only this
        // pass's own placements; `excluded` is never relaxed.
        let infos = self.registry.vo_infos(&self.vo);
        let mut holding: BTreeSet<String> = stat
            .chunks
            .iter()
            .filter(|c| c.available)
            .map(|c| c.se.clone())
            .collect();
        let mut chosen: BTreeSet<String> = BTreeSet::new();
        let base = Self::base_name(lfn)?;
        let n = params.n();
        let mut repaired = 0usize;
        for (ordinal, (idx, wire)) in rebuilt.into_iter().enumerate() {
            let eligible = |avoid: &BTreeSet<String>| -> Vec<SeInfo> {
                infos
                    .iter()
                    .filter(|s| {
                        s.available && !excluded.contains(&s.name) && !avoid.contains(&s.name)
                    })
                    .cloned()
                    .collect()
            };
            let mut candidates = eligible(&holding);
            if candidates.is_empty() {
                candidates = eligible(&chosen);
            }
            if candidates.is_empty() {
                return Err(Error::Transfer("no SE available for repair".into()));
            }
            // One placement slot per chunk; rotating the candidate list by
            // the rebuild ordinal spreads successive chunks across the
            // vector (round-robin stays round-robin) without asking the
            // policy for slots it will not use.
            candidates.rotate_left(ordinal % candidates.len());
            let slot = *self
                .policy
                .place(1, &candidates)?
                .first()
                .ok_or_else(|| Error::Ec("placement returned no slot".into()))?;
            let target = candidates[slot].name.clone();
            let se = self
                .registry
                .get(&target)
                .ok_or_else(|| Error::Config("registry inconsistent".into()))?;
            let name = chunk_name(&base, idx, n);
            let pfn = format!("{lfn}/{name}");
            se.put(&pfn, &wire)?;
            // Drop stale replica records, then register the new one.
            let old: Vec<String> = self
                .dfc
                .replicas(&pfn)?
                .iter()
                .map(|r| r.se.clone())
                .collect();
            for se_name in old {
                let _ = self.dfc.remove_replica(&pfn, &se_name);
            }
            self.dfc.register_replica(&pfn, se.name(), &pfn)?;
            holding.insert(target.clone());
            chosen.insert(target);
            repaired += 1;
        }
        Ok(repaired)
    }

    /// Delete the EC file: best-effort removal of chunk objects, then the
    /// catalog subtree.
    pub fn rm(&self, lfn: &str) -> Result<()> {
        let (_, _, chunk_files) = self.read_layout(lfn)?;
        for (_, _, replicas) in &chunk_files {
            for r in replicas {
                if let Some(se) = self.registry.get(&r.se) {
                    let _ = se.delete(&r.pfn);
                }
            }
        }
        self.dfc.remove_dir(lfn)
    }
}

/// Upload one chunk with retry/fallback (free function so the pool closure
/// stays small).
#[allow(clippy::too_many_arguments)]
fn upload_with_retry(
    ses: &[Arc<dyn StorageElement>],
    infos: &[crate::se::SeInfo],
    policy: &dyn PlacementPolicy,
    retry: RetryPolicy,
    chunk_idx: usize,
    primary: usize,
    pfn: &str,
    wire: &[u8],
) -> Result<String> {
    let mut tried: Vec<usize> = Vec::new();
    let mut target = primary;
    let mut attempts = 0usize;
    loop {
        attempts += 1;
        match ses[target].put(pfn, wire) {
            Ok(()) => return Ok(ses[target].name().to_string()),
            Err(e) => {
                tried.push(target);
                if !retry.retries_left(attempts) {
                    return Err(e);
                }
                if retry.fallback_se {
                    match policy.fallback(chunk_idx, infos, &tried) {
                        Some(next) => target = next,
                        None => return Err(e),
                    }
                }
                // !fallback_se: retry the same SE (transient failures).
            }
        }
    }
}

/// Fetch one chunk, walking its replica list, with retries.
fn fetch_with_retry(
    registry: &SeRegistry,
    replicas: &[crate::catalog::Replica],
    retry: RetryPolicy,
    index: usize,
) -> Result<(usize, Vec<u8>)> {
    let mut attempts = 0usize;
    let mut last_err = Error::Transfer(format!("chunk {index}: no replicas registered"));
    loop {
        for r in replicas {
            attempts += 1;
            match registry.get(&r.se) {
                Some(se) => match se.get(&r.pfn) {
                    Ok(bytes) => return Ok((index, bytes)),
                    Err(e) => last_err = e,
                },
                None => {
                    last_err =
                        Error::Config(format!("replica SE `{}` not in registry", r.se))
                }
            }
            if !retry.retries_left(attempts) {
                return Err(last_err);
            }
        }
        if replicas.is_empty() || !retry.retries_left(attempts) {
            return Err(last_err);
        }
    }
}
