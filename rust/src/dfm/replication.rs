//! The baseline the paper compares against: classical whole-file
//! replication, "one full copy per site".

use std::sync::Arc;

use crate::catalog::{FileEntry, ShardedDfc};
use crate::placement::PlacementPolicy;
use crate::se::SeRegistry;
use crate::transfer::{PoolConfig, WorkPool};
use crate::{Error, Result};

/// Whole-file integer replication manager.
pub struct ReplicationManager {
    dfc: Arc<ShardedDfc>,
    registry: Arc<SeRegistry>,
    policy: Arc<dyn PlacementPolicy>,
    vo: String,
}

impl ReplicationManager {
    /// Wire a replication manager over a catalogue, registry and policy
    /// for one VO.
    pub fn new(
        dfc: Arc<ShardedDfc>,
        registry: Arc<SeRegistry>,
        policy: Arc<dyn PlacementPolicy>,
        vo: impl Into<String>,
    ) -> Self {
        ReplicationManager { dfc, registry, policy, vo: vo.into() }
    }

    /// Upload `data` as `replicas` full copies on distinct SEs.
    ///
    /// `workers` parallelises across replicas (the WLCG baseline typically
    /// uploads once and uses FTS for the rest; we upload all copies from
    /// the client for a like-for-like comparison with the shim).
    pub fn put_bytes(
        &self,
        lfn: &str,
        data: &[u8],
        replicas: usize,
        workers: usize,
    ) -> Result<Vec<String>> {
        if replicas == 0 {
            return Err(Error::Config("replicas must be >= 1".into()));
        }
        let infos = self.registry.vo_infos(&self.vo);
        if infos.is_empty() {
            return Err(Error::Config(format!("no SEs support VO `{}`", self.vo)));
        }
        // Distinct SEs: walk the placement assignment, dedup preserving
        // order, extend vector-order if the policy repeated itself.
        let mut targets: Vec<usize> = Vec::new();
        for i in self.policy.place(replicas, &infos)? {
            if !targets.contains(&i) {
                targets.push(i);
            }
        }
        for i in 0..infos.len() {
            if targets.len() >= replicas {
                break;
            }
            if !targets.contains(&i) {
                targets.push(i);
            }
        }
        if targets.len() < replicas {
            return Err(Error::Config(format!(
                "need {replicas} distinct SEs, have {}",
                infos.len()
            )));
        }

        if self.dfc.exists(lfn) {
            return Err(Error::Catalog(format!("`{lfn}` already exists")));
        }

        let ses = self.registry.vo_vector(&self.vo);
        let pfn = lfn.to_string();
        let jobs: Vec<(usize, Box<dyn FnOnce() -> Result<String> + Send>)> = targets
            .iter()
            .map(|&t| {
                let se = Arc::clone(&ses[t]);
                let pfn = pfn.clone();
                let data = data.to_vec();
                let f: Box<dyn FnOnce() -> Result<String> + Send> =
                    Box::new(move || se.put(&pfn, &data).map(|()| se.name().to_string()));
                (t, f)
            })
            .collect();
        let outcome = WorkPool::new(PoolConfig::parallel(workers.max(1))).run(jobs, usize::MAX);
        if !outcome.failures.is_empty() {
            for (_, se_name) in &outcome.successes {
                if let Some(se) = self.registry.get(se_name) {
                    let _ = se.delete(&pfn);
                }
            }
            let (t, e) = &outcome.failures[0];
            return Err(Error::Transfer(format!("replica upload to SE #{t} failed: {e}")));
        }

        let digest = crate::ec::chunk::sha256(data);
        let parent = lfn.rsplit_once('/').map(|(d, _)| d).unwrap_or("");
        if !parent.is_empty() {
            self.dfc.mkdir_p(parent)?;
        }
        self.dfc.add_file(
            lfn,
            FileEntry {
                size: data.len() as u64,
                checksum: crate::util::hexfmt::encode(&digest),
                replicas: vec![],
                meta: Default::default(),
            },
        )?;
        let mut names = Vec::new();
        for (_, se_name) in &outcome.successes {
            self.dfc.register_replica(lfn, se_name, &pfn)?;
            names.push(se_name.clone());
        }
        Ok(names)
    }

    /// Fetch the file, trying replicas in catalog order (the classical
    /// data-management behaviour).
    pub fn get_bytes(&self, lfn: &str) -> Result<Vec<u8>> {
        let replicas = self.dfc.replicas(lfn)?;
        let expected_checksum = self.dfc.file(lfn)?.checksum;
        let mut last = Error::Transfer(format!("`{lfn}`: no replicas"));
        for r in &replicas {
            if let Some(se) = self.registry.get(&r.se) {
                match se.get(&r.pfn) {
                    Ok(bytes) => {
                        let digest =
                            crate::util::hexfmt::encode(&crate::ec::chunk::sha256(&bytes));
                        if digest != expected_checksum {
                            last = Error::Integrity {
                                path: lfn.into(),
                                detail: format!("replica at `{}` corrupt", r.se),
                            };
                            continue;
                        }
                        return Ok(bytes);
                    }
                    Err(e) => last = e,
                }
            }
        }
        Err(last)
    }

    /// How many replicas are currently fetchable.
    pub fn available_replicas(&self, lfn: &str) -> Result<usize> {
        let replicas = self.dfc.replicas(lfn)?;
        Ok(replicas
            .iter()
            .filter(|r| {
                self.registry
                    .get(&r.se)
                    .map(|se| se.is_available() && se.exists(&r.pfn))
                    .unwrap_or(false)
            })
            .count())
    }
}
