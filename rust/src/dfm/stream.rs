//! The block-streaming data plane: bounded-memory, pipelined
//! encode → transfer → decode over the whole chunk path.
//!
//! The paper's conclusion singles out "overheads for multiple file
//! transfers" as the biggest threat to EC competitiveness, and its
//! headline win is "parallelising access across all of the distributed
//! chunks at once". This module is that access pattern turned into the
//! default data plane:
//!
//! * **Upload** (`upload_pass`): a [`crate::ec::StreamEncoder`] feeds N
//!   per-chunk bounded double-buffered queues (capacity
//!   [`QUEUE_DEPTH`] blocks — the encoder *stalls* when a queue is full,
//!   which is the backpressure that caps memory), drained by
//!   [`crate::transfer::WorkPool`] transfer workers that append blocks to
//!   [`crate::se::ChunkSink`]s. Encode of block *b+1* overlaps transfer
//!   of block *b*; peak residency is O(N · block), never O(file).
//! * **Download** (`download_pipeline`): K per-chunk reader threads
//!   issue parallel `get_range` fetches for the *same block offset*
//!   across all K chunks at once (the GridFTP-striped-streams /
//!   LDPC-segment-parallel pattern), a [`crate::ec::StreamDecoder`]
//!   folds each block straight into the destination sink, and a failed
//!   chunk is swapped for a spare *mid-stream* — already-decoded blocks
//!   are kept, only the survivor matrix is re-derived.
//! * **Rebuild** (`rebuild_pipeline`): the repair path streams K
//!   survivors once and re-derives every lost chunk per block via the
//!   precomputed [`crate::ec::rebuild_matrix`], committing the rebuilt
//!   sinks only after the whole-file digest verifies.
//!
//! Pipeline health is exported as `transfer.stream.{blocks,bytes,stalls}`
//! metrics and per-call [`StreamStats`] (used by the bounded-memory tests
//! and `benches/streaming_path.rs`).

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::catalog::Replica;
use crate::ec::chunk::HEADER_LEN;
use crate::ec::stripe::{chunk_payload_len, segment_count};
use crate::ec::{rebuild_matrix, ChunkHeader, Codec, EncodedBlock, StreamEncoder};
use crate::obs::{tracer, SpanRef};
use crate::se::{check_up, ChunkSink, SeRegistry, StorageElement};
use crate::transfer::{PoolConfig, RetryPolicy, WorkPool};
use crate::{Error, Result};

/// Default streaming block size (`transfer_block_bytes`): 4 MiB of file
/// payload per pipeline block. See `docs/OPERATIONS.md` for tuning.
pub const DEFAULT_TRANSFER_BLOCK_BYTES: usize = 4 * 1024 * 1024;

/// Per-chunk queue capacity in blocks. Two means the encoder can build
/// block *b+1* while block *b* is in flight — classic double buffering —
/// and bounds pipeline residency at N·(2 blocks) + constants.
pub const QUEUE_DEPTH: usize = 2;

/// Pipeline health counters for one streamed transfer.
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamStats {
    /// Blocks moved through the per-chunk queues.
    pub blocks: u64,
    /// Payload bytes moved through the queues.
    pub bytes: u64,
    /// Times a producer blocked on a full queue (backpressure events).
    pub stalls: u64,
    /// Peak bytes resident in queues and in-flight writes at any instant
    /// — the bounded-memory guarantee, measured.
    pub peak_buffered_bytes: u64,
    /// Payload-block writes that began before encoding finished (header
    /// writes excluded); a positive count is direct evidence of
    /// encode/transfer overlap.
    pub overlapped_writes: u64,
    /// The [`crate::obs`] trace id of this transfer's root span (0 when
    /// tracing was disabled) — `drs put --stats` uses it to look up the
    /// per-stage span breakdown for exactly this call.
    pub trace_id: u64,
}

/// Shared accounting for one pipeline run.
#[derive(Default)]
pub(crate) struct Gauge {
    cur: AtomicU64,
    peak: AtomicU64,
    blocks: AtomicU64,
    bytes: AtomicU64,
    stalls: AtomicU64,
    overlapped: AtomicU64,
    encode_done: AtomicBool,
}

impl Gauge {
    fn add(&self, n: u64) {
        let now = self.cur.fetch_add(n, Ordering::SeqCst) + n;
        self.peak.fetch_max(now, Ordering::SeqCst);
    }

    fn sub(&self, n: u64) {
        self.cur.fetch_sub(n, Ordering::SeqCst);
    }

    fn note_block(&self, bytes: u64) {
        self.blocks.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    fn note_write(&self) {
        if !self.encode_done.load(Ordering::SeqCst) {
            self.overlapped.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub(crate) fn snapshot(&self) -> StreamStats {
        StreamStats {
            blocks: self.blocks.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            stalls: self.stalls.load(Ordering::Relaxed),
            peak_buffered_bytes: self.peak.load(Ordering::SeqCst),
            overlapped_writes: self.overlapped.load(Ordering::Relaxed),
            trace_id: 0,
        }
    }
}

/// Record a finished transfer's pipeline counters into the global
/// metrics registry.
pub(crate) fn record_stream_metrics(stats: &StreamStats) {
    let m = crate::metrics::global();
    m.add("transfer.stream.blocks", stats.blocks);
    m.add("transfer.stream.bytes", stats.bytes);
    m.add("transfer.stream.stalls", stats.stalls);
}

// ---------------------------------------------------------------------
// Bounded block queue + worker-permit semaphore (std-only primitives).
// ---------------------------------------------------------------------

struct QState<T> {
    items: VecDeque<T>,
    closed: bool,
    killed: bool,
}

/// A bounded MPSC block queue with explicit close (producer done) and
/// kill (abandon: wakes a blocked producer with its item back).
struct BlockQueue<T> {
    state: Mutex<QState<T>>,
    cv: Condvar,
    cap: usize,
}

impl<T> BlockQueue<T> {
    fn new(cap: usize) -> Self {
        BlockQueue {
            state: Mutex::new(QState { items: VecDeque::new(), closed: false, killed: false }),
            cv: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Blocking push; counts one stall if the queue was full. Returns the
    /// item back if the queue was killed.
    fn push(&self, item: T, stalls: &AtomicU64) -> std::result::Result<(), T> {
        let mut st = self.state.lock().unwrap();
        let mut stalled = false;
        while st.items.len() >= self.cap && !st.killed {
            if !stalled {
                stalls.fetch_add(1, Ordering::Relaxed);
                stalled = true;
            }
            st = self.cv.wait(st).unwrap();
        }
        if st.killed {
            return Err(item);
        }
        st.items.push_back(item);
        self.cv.notify_all();
        Ok(())
    }

    /// Blocking pop; `None` once the queue is closed (or killed) and
    /// drained.
    fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.killed {
                return None;
            }
            if let Some(x) = st.items.pop_front() {
                self.cv.notify_all();
                return Some(x);
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Producer signal: no more items will arrive.
    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.cv.notify_all();
    }

    /// Abandon the queue from either side, draining queued items so the
    /// caller can settle their accounting.
    fn kill(&self) -> Vec<T> {
        let mut st = self.state.lock().unwrap();
        st.killed = true;
        let drained = st.items.drain(..).collect();
        self.cv.notify_all();
        drained
    }

    fn was_killed(&self) -> bool {
        self.state.lock().unwrap().killed
    }
}

/// Kills every queue when dropped — placed at the top of a pipeline's
/// scope so that *any* exit path (including `?` early returns) unblocks
/// reader/writer threads before the scope joins them.
struct KillGuard<'a, T>(&'a [BlockQueue<T>]);

impl<T> Drop for KillGuard<'_, T> {
    fn drop(&mut self) {
        for q in self.0 {
            let _ = q.kill();
        }
    }
}

/// Counting semaphore gating concurrent SE writes/reads to the
/// configured transfer worker count.
struct Semaphore {
    permits: Mutex<usize>,
    cv: Condvar,
}

struct SemGuard<'a>(&'a Semaphore);

impl Semaphore {
    fn new(n: usize) -> Self {
        Semaphore { permits: Mutex::new(n.max(1)), cv: Condvar::new() }
    }

    fn acquire(&self) -> SemGuard<'_> {
        let mut p = self.permits.lock().unwrap();
        while *p == 0 {
            p = self.cv.wait(p).unwrap();
        }
        *p -= 1;
        SemGuard(self)
    }
}

impl Drop for SemGuard<'_> {
    fn drop(&mut self) {
        let mut p = self.0.permits.lock().unwrap();
        *p += 1;
        self.0.cv.notify_one();
    }
}

// ---------------------------------------------------------------------
// Byte sources and sinks.
// ---------------------------------------------------------------------

/// A resettable, length-known byte stream feeding the upload encoder.
pub(crate) trait BlockSource: Send {
    /// Total bytes the source will yield.
    fn total_len(&self) -> u64;

    /// Fill `buf`, returning bytes read (short ⇒ EOF).
    fn read_block(&mut self, buf: &mut [u8]) -> Result<usize>;

    /// Rewind to the start (hash pre-pass and retry passes re-stream).
    fn reset(&mut self) -> Result<()>;
}

/// In-memory source over a borrowed slice (`put_bytes`).
pub(crate) struct SliceSource<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> SliceSource<'a> {
    pub(crate) fn new(data: &'a [u8]) -> Self {
        SliceSource { data, pos: 0 }
    }
}

impl BlockSource for SliceSource<'_> {
    fn total_len(&self) -> u64 {
        self.data.len() as u64
    }

    fn read_block(&mut self, buf: &mut [u8]) -> Result<usize> {
        let n = buf.len().min(self.data.len() - self.pos);
        buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }

    fn reset(&mut self) -> Result<()> {
        self.pos = 0;
        Ok(())
    }
}

/// File-backed source (`put_file`): one open descriptor, block reads.
pub(crate) struct FileSource {
    file: std::fs::File,
    len: u64,
}

impl FileSource {
    pub(crate) fn open(path: &std::path::Path) -> Result<Self> {
        let file = std::fs::File::open(path)?;
        let len = file.metadata()?.len();
        Ok(FileSource { file, len })
    }
}

impl BlockSource for FileSource {
    fn total_len(&self) -> u64 {
        self.len
    }

    fn read_block(&mut self, buf: &mut [u8]) -> Result<usize> {
        use std::io::Read;
        let mut filled = 0usize;
        while filled < buf.len() {
            let n = self.file.read(&mut buf[filled..])?;
            if n == 0 {
                break;
            }
            filled += n;
        }
        Ok(filled)
    }

    fn reset(&mut self) -> Result<()> {
        use std::io::{Seek, SeekFrom};
        self.file.seek(SeekFrom::Start(0))?;
        Ok(())
    }
}

/// SHA-256 of a source, streamed block-by-block, leaving it rewound —
/// the upload's digest pre-pass (headers carry the whole-file digest and
/// are the first bytes written, so the digest must exist up front).
pub(crate) fn hash_source(src: &mut dyn BlockSource, block: usize) -> Result<[u8; 32]> {
    let mut h = crate::util::sha256::Sha256::new();
    let mut buf = vec![0u8; block.max(1)];
    loop {
        let n = src.read_block(&mut buf)?;
        if n == 0 {
            break;
        }
        h.update(&buf[..n]);
        if n < buf.len() {
            break;
        }
    }
    src.reset()?;
    Ok(h.finalize())
}

/// Ordered sink for decoded file bytes (download destination).
pub(crate) trait BlockSink {
    /// Append the next run of decoded bytes.
    fn write_block(&mut self, data: &[u8]) -> Result<()>;
}

/// Collects into a `Vec` (`get_bytes`).
pub(crate) struct VecSink(pub(crate) Vec<u8>);

impl BlockSink for VecSink {
    fn write_block(&mut self, data: &[u8]) -> Result<()> {
        self.0.extend_from_slice(data);
        Ok(())
    }
}

/// Writes straight to a local file (`get_file`).
pub(crate) struct FileSink {
    w: std::io::BufWriter<std::fs::File>,
}

impl FileSink {
    pub(crate) fn create(path: &std::path::Path) -> Result<Self> {
        // lint: allow(atomic-write) — the user's download destination,
        // not workspace state; the caller renames over it after fsync.
        Ok(FileSink { w: std::io::BufWriter::new(std::fs::File::create(path)?) })
    }

    pub(crate) fn finish(mut self) -> Result<()> {
        use std::io::Write;
        self.w.flush()?;
        // fsync before the caller renames over a (possibly pre-existing)
        // destination — the repo's tmp+fsync+rename convention
        // (`util::atomic_write`); rename-before-durable could otherwise
        // replace a good file with a truncated one on power loss.
        self.w.get_ref().sync_all()?;
        Ok(())
    }
}

impl BlockSink for FileSink {
    fn write_block(&mut self, data: &[u8]) -> Result<()> {
        use std::io::Write;
        self.w.write_all(data)?;
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Shared replica fetch.
// ---------------------------------------------------------------------

/// One ranged read against a chunk's replica list, walking replicas with
/// the retry budget — the block-fetch primitive shared by the download
/// pipeline, the rebuild pipeline and the federated reader. Each failed
/// attempt is recorded as a `retry` trace event under `parent` with the
/// replica and cause, so stalls in a trace attribute to the SE at fault.
pub(crate) fn read_replicas(
    registry: &SeRegistry,
    replicas: &[Replica],
    offset: u64,
    len: usize,
    retry: RetryPolicy,
    parent: SpanRef,
) -> Result<Vec<u8>> {
    let mut attempts = 0usize;
    let mut last = Error::Transfer("no replicas registered".into());
    loop {
        for r in replicas {
            attempts += 1;
            match registry.get(&r.se) {
                Some(se) => match se.get_range(&r.pfn, offset, len) {
                    Ok(bytes) => return Ok(bytes),
                    Err(e) => {
                        crate::transfer::retry::note_attempt(parent, &r.se, attempts, &e);
                        last = e;
                    }
                },
                None => {
                    last = Error::Config(format!("replica SE `{}` not in registry", r.se));
                    crate::transfer::retry::note_attempt(parent, &r.se, attempts, &last);
                }
            }
            if !retry.retries_left(attempts) {
                return Err(last);
            }
        }
        if replicas.is_empty() || !retry.retries_left(attempts) {
            return Err(last);
        }
    }
}

// ---------------------------------------------------------------------
// Upload.
// ---------------------------------------------------------------------

/// Pipeline sizing for one streamed transfer.
#[derive(Clone, Copy, Debug)]
pub(crate) struct PipeCfg {
    /// Concurrent SE operations (the transfer worker count).
    pub workers: usize,
    /// File bytes per pipeline block (`transfer_block_bytes`).
    pub block_bytes: usize,
    /// The transfer's root span; every pipeline-stage span
    /// (`encode-block`, `chunk-transfer`, `read_at`, `decode`, …) is
    /// recorded as its child. [`SpanRef::NONE`] when tracing is off or
    /// the caller did not open a root.
    pub parent: SpanRef,
}

/// One chunk's upload destination for a pass.
pub(crate) struct UploadTarget {
    pub index: usize,
    pub se: Arc<dyn StorageElement>,
    pub pfn: String,
}

/// A committed chunk upload.
#[derive(Clone, Debug)]
pub(crate) struct UploadOutcome {
    pub index: usize,
    pub se_name: String,
    pub pfn: String,
    pub size: u64,
    pub checksum_hex: String,
}

struct ConsumerCtx<'a> {
    q: &'a BlockQueue<Vec<u8>>,
    sem: &'a Semaphore,
    gauge: &'a Gauge,
    /// The transfer's root span (`PipeCfg::parent`); each consumer opens
    /// a `chunk-transfer` child under it.
    parent: SpanRef,
}

/// Drain one chunk's queue into its SE sink, hashing the wire bytes.
/// Every error exit kills the queue — otherwise the encoder would block
/// forever pushing blocks nobody will pop.
fn consume_chunk(
    ctx: &ConsumerCtx<'_>,
    se: &Arc<dyn StorageElement>,
    pfn: &str,
    header: &[u8],
) -> Result<(u64, String)> {
    let sp = tracer().span_with(ctx.parent, "chunk-transfer", || {
        format!("{} {pfn}", se.name())
    });
    let lane = sp.handle();
    let res = consume_chunk_steps(ctx, se, pfn, header, lane);
    if res.is_err() {
        for item in ctx.q.kill() {
            ctx.gauge.sub(item.len() as u64);
        }
    }
    sp.finish(res)
}

fn consume_chunk_steps(
    ctx: &ConsumerCtx<'_>,
    se: &Arc<dyn StorageElement>,
    pfn: &str,
    header: &[u8],
    lane: SpanRef,
) -> Result<(u64, String)> {
    // Availability is re-checked *here*, inside the transfer closure, and
    // again per block: an SE taken down between job build and execution
    // (or mid-upload) yields a clean per-chunk `Error::SeDown` instead of
    // a backend-specific I/O error.
    check_up(&**se)?;
    // Opening the sink pays the per-transfer channel setup (SRM
    // negotiation in the paper's testbed), so it gets its own stage
    // span — otherwise lane coverage under-reports on high-latency SEs.
    let mut sink = {
        // Transport detail (`endpoint= reused_conn=` for remote SEs)
        // rides along so a trace distinguishes pooled from fresh dials.
        let sp = tracer().span_with(lane, "chunk-open", || match se.transport_detail() {
            Some(t) => format!("{} {t}", se.name()),
            None => se.name().to_string(),
        });
        sp.finish(se.put_writer(pfn))?
    };
    let mut hasher = crate::util::sha256::Sha256::new();
    let mut size = 0u64;
    {
        // Header write: deliberately NOT counted in `overlapped_writes` —
        // headers go out before any block exists, so counting them would
        // make the overlap metric (and the CI gates on it) vacuous.
        let mut sp = tracer().span_with(lane, "chunk-write", || "header".into());
        let _permit = ctx.sem.acquire();
        if let Err(e) = sink.write_block(header) {
            sp.fail();
            sink.abort();
            return Err(e);
        }
    }
    hasher.update(header);
    size += header.len() as u64;
    loop {
        let popped = {
            let _sp = tracer().span(lane, "chunk-queue-wait");
            ctx.q.pop()
        };
        let Some(block) = popped else { break };
        let blen = block.len() as u64;
        let res = {
            let mut sp = tracer().span_with(lane, "chunk-write", || format!("{blen} B"));
            let _permit = ctx.sem.acquire();
            ctx.gauge.note_write();
            let r = match check_up(&**se) {
                Ok(()) => sink.write_block(&block),
                Err(e) => Err(e),
            };
            if r.is_err() {
                sp.fail();
            }
            r
        };
        ctx.gauge.sub(blen);
        match res {
            Ok(()) => {
                hasher.update(&block);
                size += blen;
            }
            Err(e) => {
                // The wrapper kills the queue on the way out.
                sink.abort();
                return Err(e);
            }
        }
    }
    if ctx.q.was_killed() {
        sink.abort();
        return Err(Error::Transfer("upload aborted: encode stream failed".into()));
    }
    {
        let sp = tracer().span(lane, "commit");
        let _permit = ctx.sem.acquire();
        sp.finish(sink.commit())?;
    }
    Ok((size, crate::util::hexfmt::encode(&hasher.finalize())))
}

fn dispatch_block(
    block: EncodedBlock,
    queues: &[BlockQueue<Vec<u8>>],
    slot_of: &BTreeMap<usize, usize>,
    alive: &mut [bool],
    gauge: &Gauge,
) {
    for (idx, row) in block.rows {
        let slot = slot_of[&idx];
        if !alive[slot] {
            continue;
        }
        let len = row.len() as u64;
        gauge.add(len);
        gauge.note_block(len);
        if queues[slot].push(row, &gauge.stalls).is_err() {
            gauge.sub(len);
            alive[slot] = false;
        }
    }
}

/// The encoder loop body: read → encode → fan out to the chunk queues.
/// Each `encoder.push`/`finish` call (read+encode of one pipeline block)
/// is traced as an `encode-block` span under `parent`; queue fan-out is
/// outside the span, so encode time and backpressure stay separable.
fn feed_loop(
    source: &mut dyn BlockSource,
    mut encoder: StreamEncoder,
    queues: &[BlockQueue<Vec<u8>>],
    slot_of: &BTreeMap<usize, usize>,
    gauge: &Gauge,
    parent: SpanRef,
) -> Result<()> {
    let mut alive = vec![true; queues.len()];
    let mut buf = vec![0u8; encoder.block_input_bytes()];
    loop {
        if alive.iter().all(|a| !*a) {
            return Ok(()); // every consumer failed; stop encoding
        }
        let got = source.read_block(&mut buf)?;
        let blocks = {
            let sp = tracer().span_with(parent, "encode-block", || format!("{got} B in"));
            sp.finish(encoder.push(&buf[..got]))?
        };
        for b in blocks {
            dispatch_block(b, queues, slot_of, &mut alive, gauge);
        }
        if got < buf.len() {
            break;
        }
    }
    let tail = {
        let sp = tracer().span_with(parent, "encode-block", || "finish".into());
        sp.finish(encoder.finish())?
    };
    if let Some(b) = tail {
        dispatch_block(b, queues, slot_of, &mut alive, gauge);
    }
    Ok(())
}

/// The encoder thread: run the feed loop, then settle the queues —
/// close them on success, kill them (consumers abort) on failure.
fn encode_feed(
    source: &mut dyn BlockSource,
    encoder: StreamEncoder,
    queues: &[BlockQueue<Vec<u8>>],
    slot_of: &BTreeMap<usize, usize>,
    gauge: &Gauge,
    parent: SpanRef,
) -> Result<()> {
    let res = feed_loop(source, encoder, queues, slot_of, gauge, parent);
    gauge.encode_done.store(true, Ordering::SeqCst);
    match res {
        Ok(()) => {
            for q in queues {
                q.close();
            }
            Ok(())
        }
        Err(e) => {
            for q in queues {
                for item in q.kill() {
                    gauge.sub(item.len() as u64);
                }
            }
            Err(e)
        }
    }
}

/// One streamed upload pass over `targets`: encode blocks on a dedicated
/// thread, drain the per-chunk queues through a [`WorkPool`], return
/// per-chunk outcomes. An `Err` means the *source/encode* side failed
/// (every sink was aborted); per-chunk transfer failures come back in
/// the second vector for the caller's retry policy.
pub(crate) fn upload_pass(
    source: &mut dyn BlockSource,
    codec: &Codec,
    file_len: u64,
    digest: [u8; 32],
    targets: &[UploadTarget],
    cfg: &PipeCfg,
    gauge: &Gauge,
) -> Result<(Vec<UploadOutcome>, Vec<(usize, Error)>)> {
    source.reset()?;
    let indices: Vec<usize> = targets.iter().map(|t| t.index).collect();
    let encoder = codec.stream_encoder_for(file_len, digest, cfg.block_bytes, &indices)?;
    let headers: Vec<[u8; HEADER_LEN]> =
        indices.iter().map(|&i| encoder.header(i)).collect::<Result<Vec<_>>>()?;
    let queues: Vec<BlockQueue<Vec<u8>>> =
        targets.iter().map(|_| BlockQueue::new(QUEUE_DEPTH)).collect();
    let slot_of: BTreeMap<usize, usize> =
        indices.iter().enumerate().map(|(s, &i)| (i, s)).collect();
    let sem = Semaphore::new(cfg.workers);

    let jobs: Vec<(usize, Box<dyn FnOnce() -> Result<UploadOutcome> + Send + '_>)> = targets
        .iter()
        .enumerate()
        .map(|(slot, t)| {
            let q = &queues[slot];
            let sem = &sem;
            let se = Arc::clone(&t.se);
            let pfn = t.pfn.clone();
            let header = headers[slot];
            let index = t.index;
            let parent = cfg.parent;
            let job: Box<dyn FnOnce() -> Result<UploadOutcome> + Send + '_> =
                Box::new(move || {
                    let ctx = ConsumerCtx { q, sem, gauge, parent };
                    consume_chunk(&ctx, &se, &pfn, &header).map(|(size, checksum_hex)| {
                        UploadOutcome {
                            index,
                            se_name: se.name().to_string(),
                            pfn: pfn.clone(),
                            size,
                            checksum_hex,
                        }
                    })
                });
            (slot, job)
        })
        .collect();

    // Every chunk consumer must be runnable concurrently or the bounded
    // queues would deadlock; the semaphore (not the pool width) enforces
    // the configured transfer-worker cap.
    let pool = WorkPool::new(PoolConfig::parallel(targets.len().max(1)));
    let (enc_res, outcome) = std::thread::scope(|s| {
        let queues_ref = &queues;
        let slots_ref = &slot_of;
        let parent = cfg.parent;
        let handle = s.spawn(move || {
            encode_feed(source, encoder, queues_ref, slots_ref, gauge, parent)
        });
        let outcome = pool.run(jobs, usize::MAX);
        let enc_res = handle
            .join()
            .unwrap_or_else(|_| Err(Error::Transfer("encoder thread panicked".into())));
        (enc_res, outcome)
    });
    enc_res?;
    let successes = outcome.successes.into_iter().map(|(_, o)| o).collect();
    let failures = outcome
        .failures
        .into_iter()
        .map(|(slot, e)| (targets[slot].index, e))
        .collect();
    Ok((successes, failures))
}

// ---------------------------------------------------------------------
// Download.
// ---------------------------------------------------------------------

/// One fetchable chunk: its code-word index and catalogue replicas.
#[derive(Clone)]
pub(crate) struct FetchChunk {
    pub index: usize,
    pub replicas: Vec<Replica>,
}

#[derive(Clone, Copy)]
struct DownGeom {
    row_block: u64,
    payload_len: u64,
    n_blocks: u64,
}

/// Validate a chunk's own header against the reference one.
fn header_agrees(h: &ChunkHeader, expect: &ChunkHeader, index: usize) -> bool {
    h.index as usize == index
        && h.k == expect.k
        && h.m == expect.m
        && h.stripe_b == expect.stripe_b
        && h.file_len == expect.file_len
        && h.payload_len == expect.payload_len
        && h.file_sha256 == expect.file_sha256
}

/// Sequentially fetch one chunk's payload blocks into its queue. Every
/// ranged read (header probe and per-block fetch) is traced as a
/// `read_at` span under `parent`.
///
/// Blocks flagged in `served` are already satisfied from the read cache
/// and are skipped entirely (no SE traffic, no queue push); an empty
/// slice means "nothing served". All other blocks stream through *one*
/// [`crate::se::ChunkSource`] per replica — the handle (and its channel
/// setup cost) is opened once and reused across blocks, falling over to
/// the next replica (re-opening) only when a read fails.
#[allow(clippy::too_many_arguments)]
fn chunk_reader(
    q: &BlockQueue<Result<Vec<u8>>>,
    sem: &Semaphore,
    gauge: &Gauge,
    registry: &SeRegistry,
    chunk: &FetchChunk,
    expect: &ChunkHeader,
    start_block: u64,
    served: &[bool],
    geom: DownGeom,
    retry: RetryPolicy,
    parent: SpanRef,
) {
    let hdr = {
        let mut sp = tracer()
            .span_with(parent, "read_at", || format!("chunk {} header", chunk.index));
        let _permit = sem.acquire();
        let r = read_replicas(registry, &chunk.replicas, 0, HEADER_LEN, retry, parent)
            .and_then(|b| ChunkHeader::decode(&b));
        if r.is_err() {
            sp.fail();
        }
        r
    };
    match hdr {
        Ok(h) if header_agrees(&h, expect, chunk.index) => {}
        Ok(_) => {
            let _ = q.push(
                Err(Error::Ec(format!(
                    "chunk {} header disagrees with the file's geometry/digest",
                    chunk.index
                ))),
                &gauge.stalls,
            );
            return;
        }
        Err(e) => {
            let _ = q.push(Err(e), &gauge.stalls);
            return;
        }
    }
    let is_served = |b: u64| served.get(b as usize).copied().unwrap_or(false);
    if (start_block..geom.n_blocks).all(is_served) {
        q.close();
        return;
    }
    let mut b = start_block;
    let mut attempts = 0usize;
    let mut last = Error::Transfer("no replicas registered".into());
    'replicas: loop {
        'walk: for r in &chunk.replicas {
            if b >= geom.n_blocks {
                break 'replicas;
            }
            let se = match registry.get(&r.se) {
                Some(se) => se,
                None => {
                    attempts += 1;
                    last = Error::Config(format!("replica SE `{}` not in registry", r.se));
                    crate::transfer::retry::note_attempt(parent, &r.se, attempts, &last);
                    if !retry.retries_left(attempts) {
                        break 'replicas;
                    }
                    continue;
                }
            };
            let mut src = match check_up(&*se).and_then(|()| se.open_reader(&r.pfn)) {
                Ok(s) => s,
                Err(e) => {
                    attempts += 1;
                    crate::transfer::retry::note_attempt(parent, &r.se, attempts, &e);
                    last = e;
                    if !retry.retries_left(attempts) {
                        break 'replicas;
                    }
                    continue;
                }
            };
            while b < geom.n_blocks {
                if is_served(b) {
                    b += 1;
                    continue;
                }
                let off = b * geom.row_block;
                let want = (geom.payload_len - off).min(geom.row_block) as usize;
                let res = {
                    let mut sp = tracer().span_with(parent, "read_at", || {
                        match se.transport_detail() {
                            Some(t) => format!("chunk {} block {b} {t}", chunk.index),
                            None => format!("chunk {} block {b}", chunk.index),
                        }
                    });
                    let _permit = sem.acquire();
                    let r2 = check_up(&*se)
                        .and_then(|()| src.read_at(HEADER_LEN as u64 + off, want));
                    if r2.is_err() {
                        sp.fail();
                    }
                    r2
                };
                match res {
                    Ok(bytes) if bytes.len() == want => {
                        gauge.add(want as u64);
                        gauge.note_block(want as u64);
                        if q.push(Ok(bytes), &gauge.stalls).is_err() {
                            gauge.sub(want as u64);
                            return;
                        }
                        b += 1;
                    }
                    Ok(short) => {
                        attempts += 1;
                        last = Error::Transfer(format!(
                            "chunk {}: short block read ({} of {want} bytes)",
                            chunk.index,
                            short.len()
                        ));
                        crate::transfer::retry::note_attempt(parent, &r.se, attempts, &last);
                        if !retry.retries_left(attempts) {
                            break 'replicas;
                        }
                        continue 'walk;
                    }
                    Err(e) => {
                        attempts += 1;
                        crate::transfer::retry::note_attempt(parent, &r.se, attempts, &e);
                        last = e;
                        if !retry.retries_left(attempts) {
                            break 'replicas;
                        }
                        // Re-open on the next replica, resuming at `b`.
                        continue 'walk;
                    }
                }
            }
            q.close();
            return;
        }
        if chunk.replicas.is_empty() || !retry.retries_left(attempts) {
            break;
        }
    }
    if b >= geom.n_blocks {
        q.close();
    } else {
        let _ = q.push(Err(last), &gauge.stalls);
    }
}

/// Find one readable, geometry-consistent header among the candidates.
/// Also used by the repair path to learn a file's digest/geometry before
/// deciding whether cached rebuilt chunks can be adopted.
pub(crate) fn probe_header(
    registry: &SeRegistry,
    codec: &Codec,
    candidates: &[FetchChunk],
    retry: RetryPolicy,
    parent: SpanRef,
) -> Result<ChunkHeader> {
    let mut last = Error::NotEnoughChunks { have: 0, need: 1 };
    for c in candidates {
        match read_replicas(registry, &c.replicas, 0, HEADER_LEN, retry, parent)
            .and_then(|b| ChunkHeader::decode(&b))
        {
            Ok(h) => {
                // A readable-but-disagreeing header is a *single-chunk*
                // corruption: remember it and keep probing the other
                // survivors, exactly like the per-reader check does.
                let geometry_ok = h
                    .params()
                    .map(|p| p == codec.params() && h.stripe_b as usize == codec.stripe_b())
                    .unwrap_or(false);
                if !geometry_ok {
                    last = Error::Ec(format!(
                        "chunk {} geometry {}+{}/{} disagrees with catalogue {}/{}",
                        c.index,
                        h.k,
                        h.m,
                        h.stripe_b,
                        codec.params(),
                        codec.stripe_b()
                    ));
                    continue;
                }
                if h.index as usize != c.index {
                    last = Error::Ec(format!(
                        "chunk header index {} disagrees with catalog index {}",
                        h.index, c.index
                    ));
                    continue;
                }
                return Ok(h);
            }
            Err(e) => last = e,
        }
    }
    Err(last)
}

/// Streamed download: parallel same-offset block fetches across K chunks,
/// block-by-block decode straight into `out`, mid-stream failover onto
/// spare chunks. Returns the decoded byte count.
///
/// The read cache sits directly under this loop: cached decoded blocks
/// are pinned up front and served without touching any SE (a fully
/// cached file costs one header probe), freshly decoded blocks are
/// admitted on the way out, and — when a chunk failed over mid-stream —
/// the lost chunk's rows are re-derived per block (the decode already
/// paid for the survivors) and retained in the degraded pool for later
/// degraded reads and repair adoption. Cache effect is surfaced as one
/// `cache` trace event per transfer.
#[allow(clippy::too_many_arguments)]
pub(crate) fn download_pipeline(
    registry: &Arc<SeRegistry>,
    codec: &Codec,
    candidates: &[FetchChunk],
    out: &mut dyn BlockSink,
    cfg: &PipeCfg,
    retry: RetryPolicy,
    gauge: &Gauge,
    cache: &crate::cache::ReadCache,
    lfn: &str,
) -> Result<u64> {
    let k = codec.params().k();
    if candidates.len() < k {
        return Err(Error::NotEnoughChunks { have: candidates.len(), need: k });
    }
    let hdr = probe_header(registry, codec, candidates, retry, cfg.parent)?;
    let sb = codec.stripe_b();
    let segs = segment_count(hdr.file_len, k, sb);
    let payload_len = chunk_payload_len(hdr.file_len, k, sb);
    if hdr.payload_len != payload_len {
        return Err(Error::Ec(format!(
            "chunk header claims payload {} but geometry implies {payload_len}",
            hdr.payload_len
        )));
    }
    let block_segs = (cfg.block_bytes / (k * sb)).max(1) as u64;
    let geom = DownGeom {
        row_block: block_segs * sb as u64,
        payload_len,
        n_blocks: segs.div_ceil(block_segs),
    };
    let digest = hdr.file_sha256;
    let use_cache = cache.enabled();
    if use_cache || cache.degraded_enabled() {
        cache.note_lfn(lfn, &digest);
    }
    // Pin every cached block up front (hit/miss accounting happens
    // here); readers are told which blocks never need fetching.
    let served: Vec<Option<Arc<Vec<u8>>>> = (0..geom.n_blocks)
        .map(|b| if use_cache { cache.get_block(&digest, geom.row_block, b) } else { None })
        .collect();
    let served_flags: Vec<bool> = served.iter().map(Option::is_some).collect();
    let hits = served_flags.iter().filter(|&&s| s).count() as u64;
    let note_cache_event = |served_bytes: u64| {
        if use_cache {
            tracer().event(cfg.parent, "cache", true, || {
                format!(
                    "hits={hits} misses={} served_bytes={served_bytes}",
                    geom.n_blocks - hits
                )
            });
        }
    };

    if use_cache && hits == geom.n_blocks {
        // Every block is cached: decode-free fast path. The bytes still
        // flow through the incremental hash, so `finish()` holds the
        // same end-to-end integrity guarantee as a cold get.
        let mut decoder = codec.stream_decoder(hdr.file_len, digest);
        let mut written = 0u64;
        for (b, data) in served.iter().enumerate() {
            let data = data.as_ref().expect("fully served");
            let bc = (segs - b as u64 * block_segs).min(block_segs);
            decoder.push_decoded(bc, data)?;
            out.write_block(data)?;
            written += data.len() as u64;
        }
        decoder.finish()?;
        note_cache_event(written);
        return Ok(written);
    }

    let sem = Semaphore::new(cfg.workers);
    let queues: Vec<BlockQueue<Result<Vec<u8>>>> =
        candidates.iter().map(|_| BlockQueue::new(QUEUE_DEPTH)).collect();

    std::thread::scope(|s| -> Result<u64> {
        // Dropped on every exit path (before the scope joins): unblocks
        // any reader still pushing prefetched blocks.
        let _kill = KillGuard(&queues);
        let queues_ref = &queues;
        let sem_ref = &sem;
        let hdr_ref = &hdr;
        let served_ref = &served_flags;
        let parent = cfg.parent;
        let spawn_reader = |slot: usize, start_block: u64| {
            let q = &queues_ref[slot];
            let chunk = &candidates[slot];
            let registry = Arc::clone(registry);
            s.spawn(move || {
                chunk_reader(
                    q, sem_ref, gauge, &registry, chunk, hdr_ref, start_block, served_ref,
                    geom, retry, parent,
                )
            });
        };
        let mut decoder = codec.stream_decoder(hdr.file_len, digest);
        let mut active: Vec<usize> = (0..k).collect();
        for slot in 0..k {
            spawn_reader(slot, 0);
        }
        let mut next_candidate = k;
        let mut written = 0u64;
        let mut served_bytes = 0u64;
        // Chunk indices that failed over mid-stream; while non-empty,
        // each decoded block also re-derives the lost chunks' rows for
        // the degraded cache.
        let mut dead: Vec<usize> = Vec::new();
        let mut rbm: Option<(Vec<usize>, Vec<usize>, crate::gf::GfMatrix)> = None;
        for b in 0..geom.n_blocks {
            if let Some(data) = &served[b as usize] {
                let bc = (segs - b * block_segs).min(block_segs);
                {
                    let sp = tracer()
                        .span_with(cfg.parent, "decode", || format!("block {b} (cached)"));
                    sp.finish(decoder.push_decoded(bc, data))?;
                }
                out.write_block(data)?;
                written += data.len() as u64;
                served_bytes += data.len() as u64;
                continue;
            }
            let mut rows: Vec<(usize, Vec<u8>)> = Vec::with_capacity(k);
            let mut pos = 0usize;
            while pos < active.len() {
                let slot = active[pos];
                match queues[slot].pop() {
                    Some(Ok(bytes)) => {
                        rows.push((candidates[slot].index, bytes));
                        pos += 1;
                    }
                    _ => {
                        // A chunk died mid-stream: swap in the next
                        // spare from block `b` onward; everything
                        // decoded so far is kept.
                        if next_candidate >= candidates.len() {
                            tracer().event(cfg.parent, "failover", false, || {
                                format!(
                                    "chunk {} died at block {b}; no spares left",
                                    candidates[slot].index
                                )
                            });
                            return Err(Error::NotEnoughChunks { have: k - 1, need: k });
                        }
                        let ns = next_candidate;
                        next_candidate += 1;
                        tracer().event(cfg.parent, "failover", true, || {
                            format!(
                                "chunk {} died at block {b}; spare chunk {} swapped in",
                                candidates[slot].index, candidates[ns].index
                            )
                        });
                        dead.push(candidates[slot].index);
                        spawn_reader(ns, b);
                        active[pos] = ns;
                    }
                }
            }
            let refs: Vec<(usize, &[u8])> =
                rows.iter().map(|(i, v)| (*i, v.as_slice())).collect();
            let bytes = {
                let sp = tracer().span_with(cfg.parent, "decode", || format!("block {b}"));
                sp.finish(decoder.push_block(&refs))?
            };
            out.write_block(&bytes)?;
            if !dead.is_empty() && cache.degraded_enabled() {
                // The survivors for this block are already in memory:
                // deriving the lost chunks' rows now costs one small
                // matmul, and saves a full K-survivor re-stream on the
                // next degraded read (or lets repair adopt them).
                let present: Vec<usize> = rows.iter().map(|(i, _)| *i).collect();
                let stale = rbm
                    .as_ref()
                    .map(|(p, d, _)| p != &present || d != &dead)
                    .unwrap_or(true);
                if stale {
                    rbm = Some((
                        present.clone(),
                        dead.clone(),
                        rebuild_matrix(codec.params(), &present, &dead)?,
                    ));
                }
                let (_, _, mat) = rbm.as_ref().expect("rebuild matrix ensured");
                let row_len = rows[0].1.len();
                let mut rebuilt: Vec<Vec<u8>> = vec![vec![0u8; row_len]; dead.len()];
                for seg in 0..row_len / sb {
                    let data_refs: Vec<&[u8]> =
                        rows.iter().map(|(_, p)| &p[seg * sb..(seg + 1) * sb]).collect();
                    let mut out_refs: Vec<&mut [u8]> = rebuilt
                        .iter_mut()
                        .map(|v| &mut v[seg * sb..(seg + 1) * sb])
                        .collect();
                    codec.backend().matmul_into(mat, &data_refs, &mut out_refs)?;
                }
                for (di, buf) in dead.iter().zip(rebuilt) {
                    cache.insert_chunk_block(&digest, *di, geom.row_block, b, buf);
                }
            }
            for (_, v) in &rows {
                gauge.sub(v.len() as u64);
            }
            written += bytes.len() as u64;
            if use_cache {
                cache.insert_block(&digest, geom.row_block, b, bytes);
            }
        }
        {
            let sp = tracer().span_with(cfg.parent, "decode", || "finish".into());
            sp.finish(decoder.finish())?;
        }
        note_cache_event(served_bytes);
        Ok(written)
    })
}

// ---------------------------------------------------------------------
// Rebuild (streaming repair).
// ---------------------------------------------------------------------

/// A lost chunk being re-derived into a destination sink.
pub(crate) struct RebuildTarget<'a> {
    pub index: usize,
    pub sink: Box<dyn ChunkSink + 'a>,
}

/// Stream K survivors once and re-derive every chunk in `targets` block
/// by block (`missing rows = R · survivor rows`), committing the sinks
/// only after the reassembled file's digest verifies. Rebuilt wire
/// chunks are bit-identical to the originals.
pub(crate) fn rebuild_pipeline(
    registry: &Arc<SeRegistry>,
    codec: &Codec,
    candidates: &[FetchChunk],
    mut targets: Vec<RebuildTarget<'_>>,
    cfg: &PipeCfg,
    retry: RetryPolicy,
    gauge: &Gauge,
) -> Result<()> {
    let params = codec.params();
    let k = params.k();
    if candidates.len() < k {
        return Err(Error::NotEnoughChunks { have: candidates.len(), need: k });
    }
    let hdr = probe_header(registry, codec, candidates, retry, cfg.parent)?;
    let sb = codec.stripe_b();
    let segs = segment_count(hdr.file_len, k, sb);
    let payload_len = chunk_payload_len(hdr.file_len, k, sb);
    if hdr.payload_len != payload_len {
        return Err(Error::Ec(format!(
            "chunk header claims payload {} but geometry implies {payload_len}",
            hdr.payload_len
        )));
    }
    let block_segs = (cfg.block_bytes / (k * sb)).max(1) as u64;
    let geom = DownGeom {
        row_block: block_segs * sb as u64,
        payload_len,
        n_blocks: segs.div_ceil(block_segs),
    };
    let missing_idx: Vec<usize> = targets.iter().map(|t| t.index).collect();
    let sem = Semaphore::new(cfg.workers);
    let queues: Vec<BlockQueue<Result<Vec<u8>>>> =
        candidates.iter().map(|_| BlockQueue::new(QUEUE_DEPTH)).collect();

    let targets_ref = &mut targets;
    let run = std::thread::scope(|s| -> Result<()> {
        // Dropped on every exit path (before the scope joins): unblocks
        // any reader still pushing prefetched blocks.
        let _kill = KillGuard(&queues);
        let queues_ref = &queues;
        let sem_ref = &sem;
        let hdr_ref = &hdr;
        let parent = cfg.parent;
        let spawn_reader = |slot: usize, start_block: u64| {
            let q = &queues_ref[slot];
            let chunk = &candidates[slot];
            let registry = Arc::clone(registry);
            s.spawn(move || {
                chunk_reader(
                    q, sem_ref, gauge, &registry, chunk, hdr_ref, start_block, &[], geom,
                    retry, parent,
                )
            });
        };
        // Headers first: rebuilt chunks carry the same sealed header
        // as the originals.
        for t in targets_ref.iter_mut() {
            let h = ChunkHeader::new(
                params,
                t.index,
                sb,
                hdr.file_len,
                payload_len,
                hdr.file_sha256,
            )
            .encode();
            t.sink.write_block(&h)?;
        }
        let mut decoder = codec.stream_decoder(hdr.file_len, hdr.file_sha256);
        let mut active: Vec<usize> = (0..k).collect();
        for slot in 0..k {
            spawn_reader(slot, 0);
        }
        let mut next_candidate = k;
        let mut rb: Option<(Vec<usize>, crate::gf::GfMatrix)> = None;
        for b in 0..geom.n_blocks {
            let mut rows: Vec<(usize, Vec<u8>)> = Vec::with_capacity(k);
            let mut pos = 0usize;
            while pos < active.len() {
                let slot = active[pos];
                match queues[slot].pop() {
                    Some(Ok(bytes)) => {
                        rows.push((candidates[slot].index, bytes));
                        pos += 1;
                    }
                    _ => {
                        if next_candidate >= candidates.len() {
                            tracer().event(cfg.parent, "failover", false, || {
                                format!(
                                    "chunk {} died at block {b}; no spares left",
                                    candidates[slot].index
                                )
                            });
                            return Err(Error::NotEnoughChunks { have: k - 1, need: k });
                        }
                        let ns = next_candidate;
                        next_candidate += 1;
                        tracer().event(cfg.parent, "failover", true, || {
                            format!(
                                "chunk {} died at block {b}; spare chunk {} swapped in",
                                candidates[slot].index, candidates[ns].index
                            )
                        });
                        spawn_reader(ns, b);
                        active[pos] = ns;
                    }
                }
            }
            // One `decode` span per rebuilt block: matrix (re)derivation,
            // the matmul fan-out and the sink writes all land inside it.
            let _sp = tracer().span_with(cfg.parent, "decode", || format!("rebuild block {b}"));
            let present: Vec<usize> = rows.iter().map(|(i, _)| *i).collect();
            let stale = rb.as_ref().map(|(p, _)| p != &present).unwrap_or(true);
            if stale {
                rb = Some((
                    present.clone(),
                    rebuild_matrix(params, &present, &missing_idx)?,
                ));
            }
            let (_, rbm) = rb.as_ref().expect("rebuild matrix ensured");
            let row_len = rows[0].1.len();
            let segs_in_block = row_len / sb;
            let mut rebuilt: Vec<Vec<u8>> = vec![vec![0u8; row_len]; targets_ref.len()];
            for seg in 0..segs_in_block {
                let data_refs: Vec<&[u8]> =
                    rows.iter().map(|(_, p)| &p[seg * sb..(seg + 1) * sb]).collect();
                let mut out_refs: Vec<&mut [u8]> = rebuilt
                    .iter_mut()
                    .map(|v| &mut v[seg * sb..(seg + 1) * sb])
                    .collect();
                codec.backend().matmul_into(rbm, &data_refs, &mut out_refs)?;
            }
            for (t, block_bytes) in targets_ref.iter_mut().zip(&rebuilt) {
                t.sink.write_block(block_bytes)?;
            }
            // Reassemble (and hash) the file bytes so the rebuilt
            // chunks only commit once the digest verifies.
            let refs: Vec<(usize, &[u8])> =
                rows.iter().map(|(i, v)| (*i, v.as_slice())).collect();
            let _ = decoder.push_block(&refs)?;
            for (_, v) in &rows {
                gauge.sub(v.len() as u64);
            }
        }
        decoder.finish()
    });

    match run {
        Ok(()) => {
            let mut err: Option<Error> = None;
            for t in targets {
                if err.is_none() {
                    if let Err(e) = t.sink.commit() {
                        err = Some(e);
                    }
                } else {
                    t.sink.abort();
                }
            }
            match err {
                None => Ok(()),
                Some(e) => Err(e),
            }
        }
        Err(e) => {
            for t in targets {
                t.sink.abort();
            }
            Err(e)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn queue_backpressure_and_close() {
        let q: BlockQueue<u32> = BlockQueue::new(2);
        let stalls = AtomicU64::new(0);
        q.push(1, &stalls).unwrap();
        q.push(2, &stalls).unwrap();
        std::thread::scope(|s| {
            s.spawn(|| {
                // Third push must block until the consumer pops.
                q.push(3, &stalls).unwrap();
                q.close();
            });
            std::thread::sleep(std::time::Duration::from_millis(20));
            assert_eq!(q.pop(), Some(1));
            assert_eq!(q.pop(), Some(2));
            assert_eq!(q.pop(), Some(3));
            assert_eq!(q.pop(), None);
        });
        assert_eq!(stalls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn queue_kill_unblocks_producer_and_returns_items() {
        let q: BlockQueue<u32> = BlockQueue::new(1);
        let stalls = AtomicU64::new(0);
        q.push(7, &stalls).unwrap();
        std::thread::scope(|s| {
            let h = s.spawn(|| q.push(8, &stalls));
            std::thread::sleep(std::time::Duration::from_millis(20));
            let drained = q.kill();
            assert_eq!(drained, vec![7]);
            assert_eq!(h.join().unwrap(), Err(8));
        });
        assert!(q.was_killed());
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn semaphore_caps_concurrency() {
        let sem = Semaphore::new(2);
        let peak = AtomicU64::new(0);
        let cur = AtomicU64::new(0);
        std::thread::scope(|s| {
            for _ in 0..6 {
                s.spawn(|| {
                    let _p = sem.acquire();
                    let now = cur.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    cur.fetch_sub(1, Ordering::SeqCst);
                });
            }
        });
        assert!(peak.load(Ordering::SeqCst) <= 2);
    }

    #[test]
    fn slice_source_reads_and_resets() {
        let data: Vec<u8> = (0..100u8).collect();
        let mut src = SliceSource::new(&data);
        assert_eq!(src.total_len(), 100);
        let mut buf = vec![0u8; 64];
        assert_eq!(src.read_block(&mut buf).unwrap(), 64);
        assert_eq!(src.read_block(&mut buf).unwrap(), 36);
        assert_eq!(src.read_block(&mut buf).unwrap(), 0);
        src.reset().unwrap();
        assert_eq!(src.read_block(&mut buf).unwrap(), 64);
        assert_eq!(&buf[..4], &[0, 1, 2, 3]);
    }

    #[test]
    fn hash_source_matches_oneshot_and_rewinds() {
        let data: Vec<u8> = (0..5000u32).map(|i| (i % 251) as u8).collect();
        let mut src = SliceSource::new(&data);
        let h = hash_source(&mut src, 97).unwrap();
        assert_eq!(h, crate::util::sha256::digest(&data));
        let mut buf = [0u8; 4];
        assert_eq!(src.read_block(&mut buf).unwrap(), 4);
        assert_eq!(buf, [0, 1, 2, 3]);
    }
}
