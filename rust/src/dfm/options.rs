//! Put/get options for the shim.

use crate::catalog::MetaKeyStyle;
use crate::ec::{EcParams, DEFAULT_STRIPE_B};
use crate::transfer::RetryPolicy;

/// Options for [`crate::dfm::EcShim::put_bytes`].
#[derive(Clone, Debug)]
pub struct PutOptions {
    /// Coding geometry (default: the paper's 10+5).
    pub params: EcParams,
    /// Stripe width per chunk row; must match an AOT artifact for the PJRT
    /// backend to be used for that geometry.
    pub stripe_b: usize,
    /// Transfer worker threads (1 = the paper's serial tool).
    pub workers: usize,
    /// Retry policy (the paper's PoC is `RetryPolicy::none()`).
    pub retry: RetryPolicy,
    /// Metadata tag style (§4: V2Prefixed avoids global-tag collisions).
    pub key_style: MetaKeyStyle,
}

impl Default for PutOptions {
    fn default() -> Self {
        PutOptions {
            params: EcParams::paper_default(),
            stripe_b: DEFAULT_STRIPE_B,
            workers: 1,
            retry: RetryPolicy::none(),
            key_style: MetaKeyStyle::V2Prefixed,
        }
    }
}

impl PutOptions {
    /// Set the coding geometry.
    pub fn with_params(mut self, params: EcParams) -> Self {
        self.params = params;
        self
    }

    /// Set the transfer worker count (clamped to ≥ 1).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Set the stripe width.
    pub fn with_stripe(mut self, stripe_b: usize) -> Self {
        self.stripe_b = stripe_b;
        self
    }

    /// Set the retry policy.
    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    /// Set the metadata tag style.
    pub fn with_key_style(mut self, style: MetaKeyStyle) -> Self {
        self.key_style = style;
        self
    }
}

/// Options for [`crate::dfm::EcShim::get_bytes`].
#[derive(Clone, Debug)]
pub struct GetOptions {
    /// Transfer worker threads (1 = serial).
    pub workers: usize,
    /// Retry policy for individual chunk fetches.
    pub retry: RetryPolicy,
}

impl Default for GetOptions {
    fn default() -> Self {
        GetOptions { workers: 1, retry: RetryPolicy::none() }
    }
}

impl GetOptions {
    /// Set the transfer worker count (clamped to ≥ 1).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Set the retry policy.
    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let p = PutOptions::default();
        assert_eq!(p.params, EcParams::new(10, 5).unwrap());
        assert_eq!(p.workers, 1);
        assert_eq!(p.retry, RetryPolicy::none());
        let g = GetOptions::default();
        assert_eq!(g.workers, 1);
    }

    #[test]
    fn builders() {
        let p = PutOptions::default()
            .with_params(EcParams::new(4, 2).unwrap())
            .with_workers(0)
            .with_stripe(1024);
        assert_eq!(p.workers, 1); // clamped
        assert_eq!(p.stripe_b, 1024);
    }
}
