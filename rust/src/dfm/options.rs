//! Put/get options for the shim.

use crate::catalog::MetaKeyStyle;
use crate::dfm::stream::DEFAULT_TRANSFER_BLOCK_BYTES;
use crate::ec::{EcParams, DEFAULT_STRIPE_B};
use crate::transfer::RetryPolicy;

/// Options for [`crate::dfm::EcShim::put_bytes`].
#[derive(Clone, Debug)]
pub struct PutOptions {
    /// Coding geometry (default: the paper's 10+5).
    pub params: EcParams,
    /// Stripe width per chunk row; must match an AOT artifact for the PJRT
    /// backend to be used for that geometry.
    pub stripe_b: usize,
    /// Transfer worker threads (1 = the paper's serial tool).
    pub workers: usize,
    /// Retry policy (the paper's PoC is `RetryPolicy::none()`).
    pub retry: RetryPolicy,
    /// Metadata tag style (§4: V2Prefixed avoids global-tag collisions).
    pub key_style: MetaKeyStyle,
    /// File bytes per streaming pipeline block (`transfer_block_bytes`):
    /// the unit of encode/transfer overlap and the memory bound's block.
    pub block_bytes: usize,
}

impl Default for PutOptions {
    fn default() -> Self {
        PutOptions {
            params: EcParams::paper_default(),
            stripe_b: DEFAULT_STRIPE_B,
            workers: 1,
            retry: RetryPolicy::none(),
            key_style: MetaKeyStyle::V2Prefixed,
            block_bytes: DEFAULT_TRANSFER_BLOCK_BYTES,
        }
    }
}

impl PutOptions {
    /// Set the coding geometry.
    pub fn with_params(mut self, params: EcParams) -> Self {
        self.params = params;
        self
    }

    /// Set the transfer worker count (clamped to ≥ 1).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Set the stripe width.
    pub fn with_stripe(mut self, stripe_b: usize) -> Self {
        self.stripe_b = stripe_b;
        self
    }

    /// Set the retry policy.
    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    /// Set the metadata tag style.
    pub fn with_key_style(mut self, style: MetaKeyStyle) -> Self {
        self.key_style = style;
        self
    }

    /// Set the streaming block size in bytes (clamped to ≥ 1).
    pub fn with_block_bytes(mut self, block_bytes: usize) -> Self {
        self.block_bytes = block_bytes.max(1);
        self
    }
}

/// Options for [`crate::dfm::EcShim::get_bytes`].
#[derive(Clone, Debug)]
pub struct GetOptions {
    /// Transfer worker threads (1 = serial).
    pub workers: usize,
    /// Retry policy for individual chunk fetches.
    pub retry: RetryPolicy,
    /// File bytes per streaming pipeline block (`transfer_block_bytes`).
    pub block_bytes: usize,
}

impl Default for GetOptions {
    fn default() -> Self {
        GetOptions {
            workers: 1,
            retry: RetryPolicy::none(),
            block_bytes: DEFAULT_TRANSFER_BLOCK_BYTES,
        }
    }
}

impl GetOptions {
    /// Set the transfer worker count (clamped to ≥ 1).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Set the retry policy.
    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    /// Set the streaming block size in bytes (clamped to ≥ 1).
    pub fn with_block_bytes(mut self, block_bytes: usize) -> Self {
        self.block_bytes = block_bytes.max(1);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let p = PutOptions::default();
        assert_eq!(p.params, EcParams::new(10, 5).unwrap());
        assert_eq!(p.workers, 1);
        assert_eq!(p.retry, RetryPolicy::none());
        assert_eq!(p.block_bytes, DEFAULT_TRANSFER_BLOCK_BYTES);
        let g = GetOptions::default();
        assert_eq!(g.workers, 1);
        assert_eq!(g.block_bytes, DEFAULT_TRANSFER_BLOCK_BYTES);
    }

    #[test]
    fn builders() {
        let p = PutOptions::default()
            .with_params(EcParams::new(4, 2).unwrap())
            .with_workers(0)
            .with_stripe(1024)
            .with_block_bytes(0);
        assert_eq!(p.workers, 1); // clamped
        assert_eq!(p.stripe_b, 1024);
        assert_eq!(p.block_bytes, 1); // clamped
        let g = GetOptions::default().with_block_bytes(8192);
        assert_eq!(g.block_bytes, 8192);
    }
}
