//! The paper's contribution: the erasure-coding file-management shim.
//!
//! §2.3's design, faithfully: the shim "treats grid storage elements
//! essentially as data archives" — whole chunks are staged through the
//! client, there is no direct IO against encoded data. `put` encodes
//! locally, creates **a directory in the DFC namespace with the filename
//! requested by the user**, stores each chunk as a DFC file inside it
//! (zfec ordinal names), tags the directory with `TOTAL`/`SPLIT`/version
//! metadata, and round-robins the chunks over the VO's SE vector. `get`
//! lists the directory, fetches until K chunks have arrived (early stop —
//! "the N fastest chunks"), reconstructs, and SHA-verifies.
//!
//! Beyond the proof of concept, the shim also implements the paper's §4
//! further-work items: transfer retries (serial and pool-safe), prefixed
//! metadata keys, and chunk repair; plus the whole-file
//! [`ReplicationManager`] baseline every benchmark compares against.
//!
//! Since the streaming-data-plane refactor the "staged through the
//! client" part no longer means *materialized in* the client: the
//! [`stream`] module moves data in bounded blocks, overlapping codec
//! work with per-chunk parallel I/O — `put`/`get` of a larger-than-RAM
//! file holds only O(N · block) bytes.

pub mod cluster;
pub mod options;
pub mod replication;
pub mod shim;
pub mod stream;

pub use cluster::TestCluster;
pub use options::{GetOptions, PutOptions};
pub use replication::ReplicationManager;
pub use shim::{EcFileStat, EcShim};
pub use stream::{StreamStats, DEFAULT_TRANSFER_BLOCK_BYTES};
