//! `TestCluster`: one-call wiring of DFC + SEs + shim, used by the
//! examples, tests and benches.

use std::sync::Arc;

use crate::catalog::ShardedDfc;
use crate::ec::{EcBackend, EcParams, PureRustBackend};
use crate::placement::{PlacementPolicy, RoundRobin};
use crate::se::{LocalSe, MemSe, NetworkProfile, SeRegistry, StorageElement};
use crate::Result;

use super::replication::ReplicationManager;
use super::shim::EcShim;

/// Builder for a self-contained cluster.
pub struct TestClusterBuilder {
    n_ses: usize,
    regions: Vec<String>,
    vo: String,
    params: EcParams,
    policy: Arc<dyn PlacementPolicy>,
    backend: Arc<dyn EcBackend>,
    local_base: Option<std::path::PathBuf>,
    profile: Option<NetworkProfile>,
    profile_scale: f64,
    catalog_shards: usize,
    cache_bytes: Option<(u64, u64)>,
}

impl TestClusterBuilder {
    /// Number of storage elements.
    pub fn ses(mut self, n: usize) -> Self {
        self.n_ses = n;
        self
    }

    /// Shard count for the catalogue namespace (default
    /// [`crate::catalog::DEFAULT_SHARDS`]; 1 reproduces the old
    /// single-mutex catalogue).
    pub fn catalog_shards(mut self, shards: usize) -> Self {
        self.catalog_shards = shards;
        self
    }

    /// Region labels, cycled over the SEs.
    pub fn regions(mut self, regions: &[&str]) -> Self {
        self.regions = regions.iter().map(|s| s.to_string()).collect();
        self
    }

    /// The VO every SE supports.
    pub fn vo(mut self, vo: &str) -> Self {
        self.vo = vo.to_string();
        self
    }

    /// Default coding geometry.
    pub fn ec(mut self, params: EcParams) -> Self {
        self.params = params;
        self
    }

    /// Placement policy.
    pub fn policy(mut self, policy: Arc<dyn PlacementPolicy>) -> Self {
        self.policy = policy;
        self
    }

    /// Coding compute backend.
    pub fn backend(mut self, backend: Arc<dyn EcBackend>) -> Self {
        self.backend = backend;
        self
    }

    /// Use directory-backed SEs rooted under `base` instead of in-memory.
    pub fn local_dirs(mut self, base: impl Into<std::path::PathBuf>) -> Self {
        self.local_base = Some(base.into());
        self
    }

    /// Attach a (scaled, really-slept) network profile to each SE.
    pub fn network(mut self, profile: NetworkProfile, scale: f64) -> Self {
        self.profile = Some(profile);
        self.profile_scale = scale;
        self
    }

    /// Enable the shim's shared read cache: `blocks` bytes for the
    /// decoded-block pool and `degraded` bytes for the rebuilt-chunk
    /// pool (either may be 0 to disable that pool). The default is
    /// fully disabled, matching the pre-cache behaviour exactly.
    pub fn cache_bytes(mut self, blocks: u64, degraded: u64) -> Self {
        self.cache_bytes = Some((blocks, degraded));
        self
    }

    /// Wire everything up.
    pub fn build(self) -> Result<TestCluster> {
        let mut registry = SeRegistry::new();
        for i in 0..self.n_ses {
            let region = self.regions[i % self.regions.len()].clone();
            let name = format!("SE-{i:02}");
            let se: Arc<dyn StorageElement> = match &self.local_base {
                Some(base) => {
                    let mut se = LocalSe::new(&name, &region, base.join(&name))?;
                    if let Some(p) = &self.profile {
                        se = se.with_profile(p.clone(), self.profile_scale);
                    }
                    Arc::new(se)
                }
                None => {
                    let mut se = MemSe::new(&name, &region);
                    if let Some(p) = &self.profile {
                        se = se.with_profile(p.clone());
                    }
                    Arc::new(se)
                }
            };
            registry.register(se, &[self.vo.as_str()])?;
        }
        let registry = Arc::new(registry);
        let dfc = Arc::new(ShardedDfc::new(self.catalog_shards));
        let cache = Arc::new(match self.cache_bytes {
            Some((blocks, degraded)) => crate::cache::ReadCache::new(blocks, degraded),
            None => crate::cache::ReadCache::disabled(),
        });
        let shim = EcShim::with_cache(
            Arc::clone(&dfc),
            Arc::clone(&registry),
            Arc::clone(&self.policy),
            Arc::clone(&self.backend),
            self.vo.clone(),
            cache,
        );
        let repl = ReplicationManager::new(
            Arc::clone(&dfc),
            Arc::clone(&registry),
            Arc::clone(&self.policy),
            self.vo.clone(),
        );
        Ok(TestCluster { dfc, registry, shim, repl, params: self.params })
    }
}

/// A wired-up cluster: catalog, SEs, shim, replication baseline.
pub struct TestCluster {
    dfc: Arc<ShardedDfc>,
    registry: Arc<SeRegistry>,
    shim: EcShim,
    repl: ReplicationManager,
    params: EcParams,
}

impl TestCluster {
    /// Start building a cluster (5 in-memory SEs, 4+2, round-robin).
    pub fn builder() -> TestClusterBuilder {
        TestClusterBuilder {
            n_ses: 5,
            regions: vec!["uk".into(), "fr".into(), "de".into()],
            vo: "demo".into(),
            params: EcParams::new(4, 2).expect("4+2 is valid"),
            policy: Arc::new(RoundRobin),
            backend: Arc::new(PureRustBackend),
            local_base: None,
            profile: None,
            profile_scale: 0.0,
            catalog_shards: crate::catalog::DEFAULT_SHARDS,
            cache_bytes: None,
        }
    }

    /// The erasure-coding shim wired over this cluster.
    pub fn shim(&self) -> &EcShim {
        &self.shim
    }

    /// The whole-file replication baseline over the same catalogue/SEs.
    pub fn replication(&self) -> &ReplicationManager {
        &self.repl
    }

    /// The SE registry.
    pub fn registry(&self) -> &SeRegistry {
        &self.registry
    }

    /// The sharded catalogue.
    pub fn dfc(&self) -> Arc<ShardedDfc> {
        Arc::clone(&self.dfc)
    }

    /// The cluster's default coding geometry.
    pub fn params(&self) -> EcParams {
        self.params
    }

    /// Take one SE offline (failure injection).
    pub fn kill_se(&self, name: &str) -> bool {
        match self.registry.get(name) {
            Some(se) => {
                se.set_available(false);
                true
            }
            None => false,
        }
    }

    /// Bring an SE back.
    pub fn revive_se(&self, name: &str) -> bool {
        match self.registry.get(name) {
            Some(se) => {
                se.set_available(true);
                true
            }
            None => false,
        }
    }

    /// Total bytes stored across all SEs (storage-overhead reporting).
    pub fn total_stored_bytes(&self) -> u64 {
        self.registry.all().iter().map(|se| se.used_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfm::{GetOptions, PutOptions};

    fn small_put_opts(cluster: &TestCluster) -> PutOptions {
        PutOptions::default()
            .with_params(cluster.params())
            .with_stripe(1024)
    }

    #[test]
    fn put_get_roundtrip_memory() {
        let cluster = TestCluster::builder().ses(5).build().unwrap();
        let data: Vec<u8> = (0..50_000u32).map(|i| (i % 251) as u8).collect();
        let opts = small_put_opts(&cluster);
        let placed = cluster
            .shim()
            .put_bytes("/vo/user/file.dat", &data, &opts)
            .unwrap();
        assert_eq!(placed.len(), 6);
        // Round-robin over 5 SEs: chunk 5 wraps to SE-00.
        assert_eq!(placed[0], "SE-00");
        assert_eq!(placed[5], "SE-00");
        let back = cluster
            .shim()
            .get_bytes("/vo/user/file.dat", &GetOptions::default())
            .unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn parallel_get_roundtrip() {
        let cluster = TestCluster::builder().ses(4).build().unwrap();
        let data = vec![0x5Au8; 30_000];
        let opts = small_put_opts(&cluster).with_workers(4);
        cluster.shim().put_bytes("/vo/p.bin", &data, &opts).unwrap();
        let back = cluster
            .shim()
            .get_bytes("/vo/p.bin", &GetOptions::default().with_workers(6))
            .unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn cached_get_roundtrip_and_hits() {
        let cluster = TestCluster::builder()
            .ses(5)
            .cache_bytes(8 << 20, 2 << 20)
            .build()
            .unwrap();
        let data: Vec<u8> = (0..77_777u32).map(|i| (i * 13) as u8).collect();
        let opts = small_put_opts(&cluster);
        cluster.shim().put_bytes("/vo/c.bin", &data, &opts).unwrap();
        let a = cluster.shim().get_bytes("/vo/c.bin", &GetOptions::default()).unwrap();
        let b = cluster.shim().get_bytes("/vo/c.bin", &GetOptions::default()).unwrap();
        assert_eq!(a, data);
        assert_eq!(b, data);
        let stats = cluster.shim().cache().stats();
        assert!(stats.hits > 0, "second get should be served from cache: {stats:?}");
        assert!(stats.resident_bytes <= 8 << 20);
        // rm must drop every cached block for the file.
        cluster.shim().rm("/vo/c.bin").unwrap();
        assert_eq!(cluster.shim().cache().stats().resident_bytes, 0);
    }

    #[test]
    fn degraded_read_survives_m_failures() {
        let cluster = TestCluster::builder().ses(6).build().unwrap();
        let data: Vec<u8> = (0..123_456u32).map(|i| (i * 7) as u8).collect();
        let opts = small_put_opts(&cluster);
        cluster.shim().put_bytes("/vo/d.bin", &data, &opts).unwrap();
        // 4+2 over 6 SEs: one chunk per SE; kill any two.
        cluster.kill_se("SE-01");
        cluster.kill_se("SE-04");
        let back = cluster
            .shim()
            .get_bytes("/vo/d.bin", &GetOptions::default().with_workers(3))
            .unwrap();
        assert_eq!(back, data);
        // A third failure makes it unreadable.
        cluster.kill_se("SE-02");
        assert!(matches!(
            cluster.shim().get_bytes("/vo/d.bin", &GetOptions::default()),
            Err(crate::Error::NotEnoughChunks { .. })
        ));
    }

    #[test]
    fn put_fails_whole_on_se_down_per_paper() {
        let cluster = TestCluster::builder().ses(5).build().unwrap();
        cluster.kill_se("SE-03");
        let opts = small_put_opts(&cluster); // RetryPolicy::none()
        let err = cluster
            .shim()
            .put_bytes("/vo/x.bin", &[1, 2, 3], &opts)
            .unwrap_err();
        assert!(matches!(err, crate::Error::Transfer(_)));
        // Catalog must be clean after the abort.
        assert!(!cluster.dfc().exists("/vo/x.bin"));
        // No stray objects left behind.
        assert_eq!(cluster.total_stored_bytes(), 0);
    }

    #[test]
    fn put_with_fallback_survives_se_down() {
        let cluster = TestCluster::builder().ses(5).build().unwrap();
        cluster.kill_se("SE-03");
        let opts = small_put_opts(&cluster)
            .with_retry(crate::transfer::RetryPolicy::default_robust());
        let placed = cluster
            .shim()
            .put_bytes("/vo/y.bin", &[9u8; 10_000], &opts)
            .unwrap();
        assert!(!placed.iter().any(|s| s == "SE-03"));
        let back = cluster
            .shim()
            .get_bytes("/vo/y.bin", &GetOptions::default())
            .unwrap();
        assert_eq!(back, vec![9u8; 10_000]);
    }

    #[test]
    fn stat_and_repair_cycle() {
        let cluster = TestCluster::builder().ses(6).build().unwrap();
        let data = vec![7u8; 65_000];
        let opts = small_put_opts(&cluster);
        cluster.shim().put_bytes("/vo/r.bin", &data, &opts).unwrap();

        let healthy = cluster.shim().stat("/vo/r.bin").unwrap();
        assert_eq!(healthy.available_chunks, 6);
        assert!(healthy.readable());

        cluster.kill_se("SE-02");
        let degraded = cluster.shim().stat("/vo/r.bin").unwrap();
        assert_eq!(degraded.degraded_by(), 1);
        assert!(degraded.readable());

        let fixed = cluster.shim().repair("/vo/r.bin", &GetOptions::default()).unwrap();
        assert_eq!(fixed, 1);
        let after = cluster.shim().stat("/vo/r.bin").unwrap();
        assert_eq!(after.available_chunks, 6);
        // The repaired chunk must not be on the dead SE.
        assert!(after.chunks.iter().all(|c| c.se != "SE-02" || !c.available || c.se != "SE-02"));
        // And the file still reads with the dead SE still down.
        let back = cluster.shim().get_bytes("/vo/r.bin", &GetOptions::default()).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn repair_spreads_rebuilt_chunks_via_policy() {
        // 4+2 over 8 SEs: chunks land on SE-00..05. Kill SE-00 and SE-01
        // — the two rebuilt chunks must go through the placement policy
        // with sibling anti-affinity, i.e. land on the two SEs holding no
        // chunk of this file (SE-06, SE-07), one each, never stacked.
        let cluster = TestCluster::builder().ses(8).build().unwrap();
        let data = vec![0xABu8; 40_000];
        let opts = small_put_opts(&cluster);
        cluster.shim().put_bytes("/vo/aa.bin", &data, &opts).unwrap();
        cluster.kill_se("SE-00");
        cluster.kill_se("SE-01");
        let fixed = cluster.shim().repair("/vo/aa.bin", &GetOptions::default()).unwrap();
        assert_eq!(fixed, 2);
        let stat = cluster.shim().stat("/vo/aa.bin").unwrap();
        assert_eq!(stat.available_chunks, 6);
        let ses: std::collections::BTreeSet<String> =
            stat.chunks.iter().map(|c| c.se.clone()).collect();
        assert_eq!(ses.len(), 6, "rebuilt chunks double-placed: {stat:?}");
        assert!(!ses.contains("SE-00") && !ses.contains("SE-01"), "{stat:?}");
        let back = cluster.shim().get_bytes("/vo/aa.bin", &GetOptions::default()).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn repair_noop_when_healthy() {
        let cluster = TestCluster::builder().ses(6).build().unwrap();
        let opts = small_put_opts(&cluster);
        cluster.shim().put_bytes("/vo/h.bin", &[1u8; 5000], &opts).unwrap();
        assert_eq!(
            cluster.shim().repair("/vo/h.bin", &GetOptions::default()).unwrap(),
            0
        );
    }

    #[test]
    fn rm_removes_objects_and_catalog() {
        let cluster = TestCluster::builder().ses(5).build().unwrap();
        let opts = small_put_opts(&cluster);
        cluster.shim().put_bytes("/vo/z.bin", &[1u8; 9000], &opts).unwrap();
        assert!(cluster.total_stored_bytes() > 0);
        cluster.shim().rm("/vo/z.bin").unwrap();
        assert_eq!(cluster.total_stored_bytes(), 0);
        assert!(!cluster.dfc().exists("/vo/z.bin"));
    }

    #[test]
    fn duplicate_put_rejected() {
        let cluster = TestCluster::builder().ses(5).build().unwrap();
        let opts = small_put_opts(&cluster);
        cluster.shim().put_bytes("/vo/dup", &[1], &opts).unwrap();
        assert!(cluster.shim().put_bytes("/vo/dup", &[2], &opts).is_err());
    }

    #[test]
    fn metadata_matches_paper_convention() {
        let cluster = TestCluster::builder().ses(5).build().unwrap();
        let opts = small_put_opts(&cluster)
            .with_key_style(crate::catalog::MetaKeyStyle::V1Generic);
        cluster.shim().put_bytes("/vo/meta.bin", &[3u8; 100], &opts).unwrap();
        let dfc = cluster.dfc();
        use crate::catalog::MetaValue;
        assert_eq!(
            dfc.get_meta("/vo/meta.bin", "TOTAL").unwrap(),
            Some(MetaValue::Int(6))
        );
        assert_eq!(
            dfc.get_meta("/vo/meta.bin", "SPLIT").unwrap(),
            Some(MetaValue::Int(4))
        );
        // The §4 pitfall is visible: generic tags in the global index.
        assert!(dfc.global_tags().contains_key("TOTAL"));
    }

    #[test]
    fn chunk_names_listed_in_catalog() {
        let cluster = TestCluster::builder().ses(5).build().unwrap();
        let opts = small_put_opts(&cluster);
        cluster.shim().put_bytes("/vo/nm.bin", &[1u8; 100], &opts).unwrap();
        let items = cluster.dfc().list_dir("/vo/nm.bin").unwrap();
        assert_eq!(items.len(), 6);
        assert_eq!(items[0].name(), "nm.bin.0_of_6.drs");
    }

    #[test]
    fn replication_baseline_roundtrip() {
        let cluster = TestCluster::builder().ses(5).build().unwrap();
        let data = vec![0xEEu8; 40_000];
        let names = cluster
            .replication()
            .put_bytes("/vo/rep.bin", &data, 2, 2)
            .unwrap();
        assert_eq!(names.len(), 2);
        assert_ne!(names[0], names[1]);
        assert_eq!(cluster.replication().get_bytes("/vo/rep.bin").unwrap(), data);
        // Storage cost is exactly 2x.
        assert_eq!(cluster.total_stored_bytes(), 80_000);
        // Survives one SE loss.
        cluster.kill_se(&names[0]);
        assert_eq!(cluster.replication().get_bytes("/vo/rep.bin").unwrap(), data);
        assert_eq!(
            cluster.replication().available_replicas("/vo/rep.bin").unwrap(),
            1
        );
    }

    #[test]
    fn ec_storage_overhead_beats_replication() {
        // The paper's efficiency claim: 10+5 stores 1.5x vs 2x for 2-rep,
        // while tolerating 5 losses vs 1.
        let cluster = TestCluster::builder()
            .ses(15)
            .ec(EcParams::new(10, 5).unwrap())
            .build()
            .unwrap();
        let data = vec![0x11u8; 200_000];
        let opts = PutOptions::default()
            .with_params(EcParams::new(10, 5).unwrap())
            .with_stripe(1024);
        cluster.shim().put_bytes("/vo/big.bin", &data, &opts).unwrap();
        let ec_bytes = cluster.total_stored_bytes() as f64;
        let overhead = ec_bytes / 200_000.0;
        assert!(
            (1.4..1.7).contains(&overhead),
            "EC overhead {overhead} should be ~1.5 (plus headers/padding)"
        );
    }
}
