//! The work-pool executor.
//!
//! Jobs are indexed closures; `run` spawns `workers` threads that pull
//! jobs off a shared queue until either the queue drains or `quota`
//! successes have accumulated (download early-stop: K of K+M chunks).
//! Jobs already in flight when the quota is reached run to completion
//! (matching real transfer threads, which cannot be usefully cancelled
//! mid-gridftp); queued jobs are abandoned.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::Result;

/// Pool sizing: `workers == 1` reproduces the paper's serial tool.
#[derive(Clone, Copy, Debug)]
pub struct PoolConfig {
    /// Worker threads pulling jobs off the queue.
    pub workers: usize,
}

impl PoolConfig {
    /// One worker (the paper's serial proof-of-concept).
    pub fn serial() -> Self {
        PoolConfig { workers: 1 }
    }

    /// `workers` threads (clamped to ≥ 1).
    pub fn parallel(workers: usize) -> Self {
        PoolConfig { workers: workers.max(1) }
    }
}

impl Default for PoolConfig {
    fn default() -> Self {
        Self::serial()
    }
}

/// Outcome of one pool run.
#[derive(Debug)]
pub struct PoolOutcome<T> {
    /// (job index, value) for every success, in completion order.
    pub successes: Vec<(usize, T)>,
    /// (job index, error) for every failure, in completion order.
    pub failures: Vec<(usize, crate::Error)>,
    /// Jobs abandoned because the quota was already met.
    pub skipped: usize,
}

impl<T> PoolOutcome<T> {
    /// How many jobs succeeded.
    pub fn success_count(&self) -> usize {
        self.successes.len()
    }
}

/// A fixed-size work pool over indexed blocking jobs.
pub struct WorkPool {
    config: PoolConfig,
}

impl WorkPool {
    /// A pool with the given sizing.
    pub fn new(config: PoolConfig) -> Self {
        WorkPool { config }
    }

    /// Run `jobs`, stopping issue of new jobs once `quota` have succeeded.
    /// `quota >= jobs.len()` means "run everything" (upload mode).
    pub fn run<T, F>(&self, jobs: Vec<(usize, F)>, quota: usize) -> PoolOutcome<T>
    where
        T: Send,
        F: FnOnce() -> Result<T> + Send,
    {
        let queue = Mutex::new(jobs.into_iter().collect::<std::collections::VecDeque<_>>());
        let successes = Mutex::new(Vec::new());
        let failures = Mutex::new(Vec::new());
        let success_count = AtomicUsize::new(0);
        let skipped = AtomicUsize::new(0);

        std::thread::scope(|scope| {
            for _ in 0..self.config.workers {
                scope.spawn(|| loop {
                    if success_count.load(Ordering::SeqCst) >= quota {
                        // Quota met: drain-and-skip the rest.
                        let mut q = crate::util::lock(&queue);
                        skipped.fetch_add(q.len(), Ordering::SeqCst);
                        q.clear();
                        return;
                    }
                    let job = crate::util::lock(&queue).pop_front();
                    let Some((idx, f)) = job else { return };
                    match f() {
                        Ok(v) => {
                            crate::util::lock(&successes).push((idx, v));
                            success_count.fetch_add(1, Ordering::SeqCst);
                        }
                        Err(e) => {
                            // Failed jobs leave a trace event (parentless:
                            // the pool has no view of the caller's span)
                            // so `drs trace tail` shows *which* job of a
                            // pass failed even when the caller retries.
                            crate::obs::tracer().event(
                                crate::obs::SpanRef::NONE,
                                "pool-job-error",
                                false,
                                || format!("job {idx}: {e}"),
                            );
                            crate::util::lock(&failures).push((idx, e));
                        }
                    }
                });
            }
        });

        // A panicking job poisons the result mutexes but leaves the
        // vectors structurally intact — recover rather than cascade.
        PoolOutcome {
            successes: successes.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner),
            failures: failures.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner),
            skipped: skipped.into_inner(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::Error;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn jobs_ok(n: usize) -> Vec<(usize, impl FnOnce() -> Result<usize> + Send)> {
        (0..n).map(|i| (i, move || Ok(i * 10))).collect()
    }

    #[test]
    fn runs_everything_when_quota_large() {
        let pool = WorkPool::new(PoolConfig::parallel(4));
        let out = pool.run(jobs_ok(10), usize::MAX);
        assert_eq!(out.success_count(), 10);
        assert_eq!(out.failures.len(), 0);
        assert_eq!(out.skipped, 0);
        let mut vals: Vec<usize> = out.successes.iter().map(|(_, v)| *v).collect();
        vals.sort_unstable();
        assert_eq!(vals, (0..10).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn early_stop_at_quota() {
        // Serial pool: exactly quota jobs run, the rest are skipped.
        let ran = AtomicUsize::new(0);
        let jobs: Vec<(usize, _)> = (0..15)
            .map(|i| {
                let ran = &ran;
                (i, move || {
                    ran.fetch_add(1, Ordering::SeqCst);
                    Ok(i)
                })
            })
            .collect();
        let out = WorkPool::new(PoolConfig::serial()).run(jobs, 10);
        assert_eq!(out.success_count(), 10);
        assert_eq!(ran.load(Ordering::SeqCst), 10);
        assert_eq!(out.skipped, 5);
    }

    #[test]
    fn early_stop_parallel_bounded_overshoot() {
        // With w workers at most w-1 extra jobs can already be in flight
        // when the quota lands.
        let ran = AtomicUsize::new(0);
        let jobs: Vec<(usize, _)> = (0..30)
            .map(|i| {
                let ran = &ran;
                (i, move || {
                    ran.fetch_add(1, Ordering::SeqCst);
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    Ok(i)
                })
            })
            .collect();
        let workers = 4;
        let out = WorkPool::new(PoolConfig::parallel(workers)).run(jobs, 10);
        assert!(out.success_count() >= 10);
        let total = ran.load(Ordering::SeqCst);
        assert!(total <= 10 + workers, "ran {total}");
    }

    #[test]
    fn failures_do_not_count_toward_quota() {
        let jobs: Vec<(usize, Box<dyn FnOnce() -> Result<usize> + Send>)> = (0..10)
            .map(|i| {
                let f: Box<dyn FnOnce() -> Result<usize> + Send> = if i % 2 == 0 {
                    Box::new(move || Err(Error::Transfer(format!("job {i}"))))
                } else {
                    Box::new(move || Ok(i))
                };
                (i, f)
            })
            .collect();
        let out = WorkPool::new(PoolConfig::parallel(3)).run(jobs, 5);
        assert_eq!(out.success_count(), 5);
        assert_eq!(out.failures.len(), 5);
    }

    #[test]
    fn zero_jobs() {
        let out = WorkPool::new(PoolConfig::parallel(2)).run(jobs_ok(0), 5);
        assert_eq!(out.success_count(), 0);
        assert_eq!(out.skipped, 0);
    }

    #[test]
    fn single_worker_preserves_queue_order() {
        let out = WorkPool::new(PoolConfig::serial()).run(jobs_ok(8), usize::MAX);
        let idxs: Vec<usize> = out.successes.iter().map(|(i, _)| *i).collect();
        assert_eq!(idxs, (0..8).collect::<Vec<_>>());
    }
}
