//! The §2.4 transfer machinery: a work-pool of transfer threads with
//! early termination and per-op retries.
//!
//! The paper: *"a user-defined set of worker threads are created, and
//! consume file transfer operations until enough chunks have been fetched
//! in total ... In the limit where the number of threads is equal to the
//! number of chunks, we essentially select the N fastest chunks out of the
//! total stripe."* [`pool::WorkPool`] implements exactly that model with
//! std threads (transfers are blocking calls against the SE trait).
//!
//! Retries are the paper's §4 further-work feature; [`retry::RetryPolicy`]
//! implements both the easy serial variant and the pool-safe variant that
//! re-queues onto a fallback SE.

pub mod pool;
pub mod retry;

pub use pool::{PoolConfig, PoolOutcome, WorkPool};
pub use retry::{Backoff, RetryPolicy};
