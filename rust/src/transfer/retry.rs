//! Transfer retry policy (the paper's §4 further-work feature) and the
//! jittered exponential backoff used by reconnecting transports.

/// How a failed chunk transfer is retried.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts per operation (1 = the paper's proof-of-concept:
    /// "any failed transfer for any chunk will cause an upload to fail").
    pub max_attempts: usize,
    /// On upload failure, whether to fall back to the next SE in the
    /// vector ("trying the next SE in the list ... disrupts the
    /// distribution of chunks across the vector" — we do it anyway and let
    /// the repair path re-balance later).
    pub fallback_se: bool,
}

impl RetryPolicy {
    /// The paper's proof-of-concept behaviour: no retries at all.
    pub fn none() -> Self {
        RetryPolicy { max_attempts: 1, fallback_se: false }
    }

    /// Sensible production default.
    pub fn default_robust() -> Self {
        RetryPolicy { max_attempts: 3, fallback_se: true }
    }

    /// Whether another attempt is allowed after `attempts_made`.
    pub fn retries_left(&self, attempts_made: usize) -> bool {
        attempts_made < self.max_attempts
    }
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self::none()
    }
}

/// Exponential backoff with deterministic jitter for reconnect loops.
///
/// Delay for attempt `n` (0-based) is `base · 2ⁿ` capped at `cap`, then
/// scaled by a jitter factor drawn uniformly from
/// `[1 − jitter_frac, 1 + jitter_frac]` via the caller's
/// [`crate::util::prng::Rng`]. The jitter is the point: after a chunk
/// server restarts, every client of every striped transfer notices at
/// the same instant, and un-jittered backoff would re-dial the endpoint
/// in synchronized waves (the classic thundering herd). Determinism is
/// kept by seeding the RNG from stable inputs, so tests replay exactly.
#[derive(Clone, Copy, Debug)]
pub struct Backoff {
    /// First-retry delay.
    pub base: std::time::Duration,
    /// Ceiling on the un-jittered delay.
    pub cap: std::time::Duration,
    /// Multiplicative jitter half-width in `[0, 1)` (0 = deterministic).
    pub jitter_frac: f64,
}

impl Backoff {
    /// Defaults tuned for LAN reconnects: 25 ms base, 2 s cap, ±50%.
    pub fn default_lan() -> Self {
        Backoff {
            base: std::time::Duration::from_millis(25),
            cap: std::time::Duration::from_secs(2),
            jitter_frac: 0.5,
        }
    }

    /// The jittered delay before retry number `attempt` (0-based).
    pub fn delay(
        &self,
        attempt: usize,
        rng: &mut crate::util::prng::Rng,
    ) -> std::time::Duration {
        let exp = self
            .base
            .saturating_mul(1u32.checked_shl(attempt.min(30) as u32).unwrap_or(u32::MAX))
            .min(self.cap);
        let j = self.jitter_frac.clamp(0.0, 0.999);
        // Uniform in [1-j, 1+j]; rng.f64() is uniform in [0, 1).
        let factor = 1.0 - j + 2.0 * j * rng.f64();
        exp.mul_f64(factor)
    }
}

impl Default for Backoff {
    fn default() -> Self {
        Self::default_lan()
    }
}

/// Record one failed attempt against a replica as a `retry` trace event
/// (zero-duration, `ok = false`) under `parent`. Free when tracing is
/// disabled: the detail string is only built for an enabled tracer.
pub(crate) fn note_attempt(
    parent: crate::obs::SpanRef,
    se: &str,
    attempt: usize,
    err: &crate::Error,
) {
    crate::obs::tracer()
        .event(parent, "retry", false, || format!("se {se} attempt {attempt}: {err}"));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_single_shot() {
        let r = RetryPolicy::none();
        assert!(r.retries_left(0));
        assert!(!r.retries_left(1));
        assert!(!r.fallback_se);
    }

    #[test]
    fn backoff_jitter_stays_in_bounds() {
        let b = Backoff {
            base: std::time::Duration::from_millis(100),
            cap: std::time::Duration::from_secs(4),
            jitter_frac: 0.5,
        };
        let mut rng = crate::util::prng::Rng::new(0xB0FF);
        for attempt in 0..12 {
            let exp_ms = (100u128 << attempt.min(30)).min(4_000);
            let lo = exp_ms as f64 * 0.5;
            let hi = exp_ms as f64 * 1.5;
            for _ in 0..200 {
                let d = b.delay(attempt, &mut rng).as_secs_f64() * 1e3;
                assert!(
                    d >= lo - 1e-9 && d <= hi + 1e-9,
                    "attempt {attempt}: {d} ms outside [{lo}, {hi}]"
                );
            }
        }
    }

    #[test]
    fn backoff_is_deterministic_per_seed() {
        let b = Backoff::default_lan();
        let mut a = crate::util::prng::Rng::new(7);
        let mut c = crate::util::prng::Rng::new(7);
        for attempt in 0..6 {
            assert_eq!(b.delay(attempt, &mut a), b.delay(attempt, &mut c));
        }
    }

    #[test]
    fn backoff_zero_jitter_is_pure_exponential() {
        let b = Backoff {
            base: std::time::Duration::from_millis(10),
            cap: std::time::Duration::from_millis(80),
            jitter_frac: 0.0,
        };
        let mut rng = crate::util::prng::Rng::new(1);
        let ms: Vec<u128> =
            (0..5).map(|a| b.delay(a, &mut rng).as_millis()).collect();
        assert_eq!(ms, vec![10, 20, 40, 80, 80], "doubling then capped");
    }

    #[test]
    fn robust_allows_three() {
        let r = RetryPolicy::default_robust();
        assert!(r.retries_left(2));
        assert!(!r.retries_left(3));
        assert!(r.fallback_se);
    }
}
