//! Transfer retry policy (the paper's §4 further-work feature).

/// How a failed chunk transfer is retried.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts per operation (1 = the paper's proof-of-concept:
    /// "any failed transfer for any chunk will cause an upload to fail").
    pub max_attempts: usize,
    /// On upload failure, whether to fall back to the next SE in the
    /// vector ("trying the next SE in the list ... disrupts the
    /// distribution of chunks across the vector" — we do it anyway and let
    /// the repair path re-balance later).
    pub fallback_se: bool,
}

impl RetryPolicy {
    /// The paper's proof-of-concept behaviour: no retries at all.
    pub fn none() -> Self {
        RetryPolicy { max_attempts: 1, fallback_se: false }
    }

    /// Sensible production default.
    pub fn default_robust() -> Self {
        RetryPolicy { max_attempts: 3, fallback_se: true }
    }

    /// Whether another attempt is allowed after `attempts_made`.
    pub fn retries_left(&self, attempts_made: usize) -> bool {
        attempts_made < self.max_attempts
    }
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self::none()
    }
}

/// Record one failed attempt against a replica as a `retry` trace event
/// (zero-duration, `ok = false`) under `parent`. Free when tracing is
/// disabled: the detail string is only built for an enabled tracer.
pub(crate) fn note_attempt(
    parent: crate::obs::SpanRef,
    se: &str,
    attempt: usize,
    err: &crate::Error,
) {
    crate::obs::tracer()
        .event(parent, "retry", false, || format!("se {se} attempt {attempt}: {err}"));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_single_shot() {
        let r = RetryPolicy::none();
        assert!(r.retries_left(0));
        assert!(!r.retries_left(1));
        assert!(!r.fallback_se);
    }

    #[test]
    fn robust_allows_three() {
        let r = RetryPolicy::default_robust();
        assert!(r.retries_left(2));
        assert!(!r.retries_left(3));
        assert!(r.fallback_se);
    }
}
