//! Configuration: JSON file + environment overrides.
//!
//! A workspace config (`drs.json`) describes the cluster the CLI operates
//! on: SEs (name, region), the VO, coding geometry, placement policy and
//! network profile. Environment variables (`DRS_*`) override scalar
//! fields; the serde/toml crates are unavailable offline so the format is
//! the crate's own JSON (see `util::json`).
//!
//! ```json
//! {
//!   "vo": "na62",
//!   "ec": {"k": 10, "m": 5, "stripe_b": 65536},
//!   "ec_backend": "auto",
//!   "placement": "round-robin",
//!   "workers": 5,
//!   "transfer_block_bytes": 4194304,
//!   "cache_bytes": 268435456,
//!   "cache_degraded_bytes": 67108864,
//!   "catalog_shards": 8,
//!   "journal_segment_bytes": 1048576,
//!   "journal_checkpoint_ops": 1024,
//!   "maintain_scrub_interval_s": 30.0,
//!   "maintain_scrub_slice": 64,
//!   "maintain_deep_every": 4,
//!   "maintain_repair_budget_files": 0,
//!   "maintain_repair_budget_mb": 0,
//!   "obs_trace": false,
//!   "obs_trace_buffer": 4096,
//!   "obs_trace_file_bytes": 4194304,
//!   "obs_status_addr": "",
//!   "maintain_drain_after_passes": 0,
//!   "remote_connect_timeout_ms": 5000,
//!   "remote_io_timeout_ms": 30000,
//!   "remote_pool_max_idle": 4,
//!   "remote_pool_idle_secs": 60,
//!   "remote_pipeline_window": 4,
//!   "ses": [
//!     {"name": "UKI-GLASGOW", "region": "uk"},
//!     {"name": "UKI-IC", "region": "uk", "endpoint": "10.0.0.7:7070"}
//!   ],
//!   "network": {"setup_s": 5.5, "bandwidth_bps": 17300000.0}
//! }
//! ```

use std::path::Path;

use crate::ec::{BackendChoice, EcParams};
use crate::se::NetworkProfile;
use crate::util::json::Json;
use crate::{Error, Result};

/// One SE declaration.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SeConfig {
    /// SE name (also its directory under `<workspace>/ses/`).
    pub name: String,
    /// Geographical region label.
    pub region: String,
    /// When set (`host:port`), the SE is a *remote* chunk server reached
    /// via [`crate::se::RemoteSe`] instead of a local directory; the
    /// `drs serve` instance at that address must serve an SE of the same
    /// name (the handshake checks).
    pub endpoint: Option<String>,
}

/// Placement policy selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum PolicyKind {
    /// The paper's `chunk n → SE (n mod s)` policy.
    #[default]
    RoundRobin,
    /// Seeded uniform random placement.
    Random,
    /// Least-loaded-first placement.
    Weighted,
    /// Prefer SEs in the client's region, pad with the rest.
    RegionAware,
}

impl PolicyKind {
    /// Parse a policy name as it appears in `drs.json`.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "round-robin" => Ok(PolicyKind::RoundRobin),
            "random" => Ok(PolicyKind::Random),
            "weighted" => Ok(PolicyKind::Weighted),
            "region-aware" => Ok(PolicyKind::RegionAware),
            other => Err(Error::Config(format!("unknown placement policy `{other}`"))),
        }
    }

    /// The policy's `drs.json` spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            PolicyKind::RoundRobin => "round-robin",
            PolicyKind::Random => "random",
            PolicyKind::Weighted => "weighted",
            PolicyKind::RegionAware => "region-aware",
        }
    }

    /// Instantiate the policy (region-aware needs the client region).
    pub fn build(&self, client_region: &str, k_plus_m: usize) -> std::sync::Arc<dyn crate::placement::PlacementPolicy> {
        use crate::placement::*;
        match self {
            PolicyKind::RoundRobin => std::sync::Arc::new(RoundRobin),
            PolicyKind::Random => std::sync::Arc::new(Random::new(0xD15C)),
            PolicyKind::Weighted => std::sync::Arc::new(Weighted),
            PolicyKind::RegionAware => std::sync::Arc::new(RegionAware {
                client_region: client_region.to_string(),
                min_ses: k_plus_m,
            }),
        }
    }
}

/// Full workspace configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Virtual organisation whose SE vector the shim places over.
    pub vo: String,
    /// Coding geometry (K data + M coding chunks).
    pub params: EcParams,
    /// Stripe width in bytes.
    pub stripe_b: usize,
    /// Which GF(2⁸) compute backend the codec uses
    /// (`auto|scalar|ssse3|avx2`). `auto` picks the fastest the CPU
    /// supports at startup; forcing an unsupported backend fails the
    /// workspace open with a clear error. All backends produce
    /// byte-identical chunks (see `tests/gf_backend_equivalence.rs`).
    pub ec_backend: BackendChoice,
    /// Chunk → SE placement policy.
    pub policy: PolicyKind,
    /// Client region (used by the region-aware policy).
    pub client_region: String,
    /// Default transfer worker threads.
    pub workers: usize,
    /// Streaming data plane: file bytes per pipeline block (the unit of
    /// encode/transfer overlap; peak transfer memory is
    /// N·(2 blocks) + constants). See docs/OPERATIONS.md for tuning.
    pub transfer_block_bytes: usize,
    /// Decoded-block read cache capacity in bytes
    /// ([`crate::cache::ReadCache`]); 0 disables the cache. Bounds
    /// *payload* residency; see docs/OPERATIONS.md for sizing.
    pub cache_bytes: u64,
    /// Degraded-read rebuilt-chunk cache capacity in bytes; 0 disables
    /// it (degraded reads then re-derive lost chunks every time and
    /// repair never adopts cached chunks).
    pub cache_degraded_bytes: u64,
    /// The storage elements the workspace wires up.
    pub ses: Vec<SeConfig>,
    /// Optional simulated network profile attached to each SE.
    pub network: Option<NetworkProfile>,
    /// Shard count for the catalogue namespace
    /// ([`crate::catalog::ShardedDfc`]); 1 reproduces the old
    /// single-mutex catalogue.
    pub catalog_shards: usize,
    /// Catalogue journal: roll to a new segment file once the current
    /// one exceeds this many bytes.
    pub journal_segment_bytes: u64,
    /// Catalogue journal: write a per-shard checkpoint after this many
    /// appended ops (bounds recovery replay length).
    pub journal_checkpoint_ops: u64,
    /// `drs maintain`: seconds the daemon sleeps between scheduler ticks.
    pub maintain_scrub_interval_s: f64,
    /// `drs maintain`: EC directories scrubbed per tick (0 = the whole
    /// subtree every tick).
    pub maintain_scrub_slice: usize,
    /// `drs maintain`: every Nth full namespace pass runs a deep
    /// (checksum) scrub; 0 disables deep passes, 1 makes every pass deep.
    pub maintain_deep_every: u64,
    /// `drs maintain`: per-tick repair budget, max files (0 = unlimited).
    pub maintain_repair_budget_files: usize,
    /// `drs maintain`: per-tick repair budget, max rebuilt megabytes
    /// (0 = unlimited).
    pub maintain_repair_budget_mb: u64,
    /// Enable transfer tracing ([`crate::obs`]): spans are recorded to
    /// the in-memory ring and appended to `<workspace>/obs_trace.jsonl`.
    /// Off by default — the disabled path is a single atomic load.
    pub obs_trace: bool,
    /// Capacity (spans) of the in-memory trace ring buffer.
    pub obs_trace_buffer: usize,
    /// Rotate `obs_trace.jsonl` once it exceeds this many bytes (the
    /// previous log is kept as `obs_trace.jsonl.1`).
    pub obs_trace_file_bytes: u64,
    /// Default address for the live HTTP status endpoint (`drs maintain
    /// --status-addr`, `drs status --serve`); empty = no endpoint unless
    /// given on the command line.
    pub obs_status_addr: String,
    /// `drs maintain`: auto-drain an SE observed dark for this many
    /// consecutive completed namespace passes (0 = never auto-drain).
    pub maintain_drain_after_passes: u64,
    /// Remote SEs: TCP connect deadline per dial attempt, milliseconds.
    pub remote_connect_timeout_ms: u64,
    /// Remote SEs: read/write deadline on established connections,
    /// milliseconds.
    pub remote_io_timeout_ms: u64,
    /// Remote SEs: max idle pooled connections per endpoint (0 disables
    /// pooling — every operation dials fresh).
    pub remote_pool_max_idle: usize,
    /// Remote SEs: park lifetime of an idle pooled connection, seconds.
    pub remote_pool_idle_secs: u64,
    /// Remote SEs: streamed-upload pipeline window — `WriteBlock` frames
    /// allowed in flight ahead of their acks (min 1).
    pub remote_pipeline_window: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            vo: "demo".into(),
            params: EcParams::paper_default(),
            stripe_b: crate::ec::DEFAULT_STRIPE_B,
            ec_backend: BackendChoice::Auto,
            policy: PolicyKind::RoundRobin,
            client_region: "uk".into(),
            workers: 1,
            transfer_block_bytes: crate::dfm::DEFAULT_TRANSFER_BLOCK_BYTES,
            cache_bytes: 256 << 20,
            cache_degraded_bytes: 64 << 20,
            ses: (0..15)
                .map(|i| SeConfig {
                    name: format!("SE-{i:02}"),
                    region: ["uk", "fr", "de"][i % 3].into(),
                    endpoint: None,
                })
                .collect(),
            network: None,
            catalog_shards: crate::catalog::DEFAULT_SHARDS,
            journal_segment_bytes: crate::catalog::DEFAULT_SEGMENT_BYTES,
            journal_checkpoint_ops: crate::catalog::DEFAULT_CHECKPOINT_OPS,
            maintain_scrub_interval_s: 30.0,
            maintain_scrub_slice: 64,
            maintain_deep_every: 4,
            maintain_repair_budget_files: 0,
            maintain_repair_budget_mb: 0,
            obs_trace: false,
            obs_trace_buffer: crate::obs::DEFAULT_BUFFER_SPANS,
            obs_trace_file_bytes: 4 << 20,
            obs_status_addr: String::new(),
            maintain_drain_after_passes: 0,
            remote_connect_timeout_ms: 5_000,
            remote_io_timeout_ms: 30_000,
            remote_pool_max_idle: 4,
            remote_pool_idle_secs: 60,
            remote_pipeline_window: 4,
        }
    }
}

impl Config {
    /// Parse a config, filling unset fields from the defaults.
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = Config::default();
        if let Some(vo) = j.get("vo").and_then(Json::as_str) {
            cfg.vo = vo.to_string();
        }
        if let Some(ec) = j.get("ec") {
            let k = ec.get("k").and_then(Json::as_u64).unwrap_or(10) as usize;
            let m = ec.get("m").and_then(Json::as_u64).unwrap_or(5) as usize;
            cfg.params = EcParams::new(k, m)?;
            if let Some(sb) = ec.get("stripe_b").and_then(Json::as_u64) {
                cfg.stripe_b = sb as usize;
            }
        }
        if let Some(b) = j.get("ec_backend").and_then(Json::as_str) {
            cfg.ec_backend = BackendChoice::parse(b)?;
        }
        if let Some(p) = j.get("placement").and_then(Json::as_str) {
            cfg.policy = PolicyKind::parse(p)?;
        }
        if let Some(r) = j.get("client_region").and_then(Json::as_str) {
            cfg.client_region = r.to_string();
        }
        if let Some(w) = j.get("workers").and_then(Json::as_u64) {
            cfg.workers = (w as usize).max(1);
        }
        if let Some(b) = j.get("transfer_block_bytes").and_then(Json::as_u64) {
            cfg.transfer_block_bytes = (b as usize).max(1);
        }
        if let Some(b) = j.get("cache_bytes").and_then(Json::as_u64) {
            cfg.cache_bytes = b;
        }
        if let Some(b) = j.get("cache_degraded_bytes").and_then(Json::as_u64) {
            cfg.cache_degraded_bytes = b;
        }
        if let Some(s) = j.get("catalog_shards").and_then(Json::as_u64) {
            cfg.catalog_shards = (s as usize).max(1);
        }
        if let Some(b) = j.get("journal_segment_bytes").and_then(Json::as_u64) {
            cfg.journal_segment_bytes = b.max(1);
        }
        if let Some(n) = j.get("journal_checkpoint_ops").and_then(Json::as_u64) {
            cfg.journal_checkpoint_ops = n.max(1);
        }
        if let Some(s) = j.get("maintain_scrub_interval_s").and_then(Json::as_f64) {
            cfg.maintain_scrub_interval_s = s.max(0.0);
        }
        if let Some(n) = j.get("maintain_scrub_slice").and_then(Json::as_u64) {
            cfg.maintain_scrub_slice = n as usize;
        }
        if let Some(n) = j.get("maintain_deep_every").and_then(Json::as_u64) {
            cfg.maintain_deep_every = n;
        }
        if let Some(n) = j.get("maintain_repair_budget_files").and_then(Json::as_u64) {
            cfg.maintain_repair_budget_files = n as usize;
        }
        if let Some(n) = j.get("maintain_repair_budget_mb").and_then(Json::as_u64) {
            cfg.maintain_repair_budget_mb = n;
        }
        if let Some(b) = j.get("obs_trace").and_then(Json::as_bool) {
            cfg.obs_trace = b;
        }
        if let Some(n) = j.get("obs_trace_buffer").and_then(Json::as_u64) {
            cfg.obs_trace_buffer = (n as usize).max(1);
        }
        if let Some(n) = j.get("obs_trace_file_bytes").and_then(Json::as_u64) {
            cfg.obs_trace_file_bytes = n.max(1);
        }
        if let Some(a) = j.get("obs_status_addr").and_then(Json::as_str) {
            cfg.obs_status_addr = a.to_string();
        }
        if let Some(n) = j.get("maintain_drain_after_passes").and_then(Json::as_u64) {
            cfg.maintain_drain_after_passes = n;
        }
        if let Some(n) = j.get("remote_connect_timeout_ms").and_then(Json::as_u64) {
            cfg.remote_connect_timeout_ms = n.max(1);
        }
        if let Some(n) = j.get("remote_io_timeout_ms").and_then(Json::as_u64) {
            cfg.remote_io_timeout_ms = n.max(1);
        }
        if let Some(n) = j.get("remote_pool_max_idle").and_then(Json::as_u64) {
            cfg.remote_pool_max_idle = n as usize;
        }
        if let Some(n) = j.get("remote_pool_idle_secs").and_then(Json::as_u64) {
            cfg.remote_pool_idle_secs = n.max(1);
        }
        if let Some(n) = j.get("remote_pipeline_window").and_then(Json::as_u64) {
            cfg.remote_pipeline_window = (n as usize).max(1);
        }
        if let Some(ses) = j.get("ses").and_then(Json::as_arr) {
            cfg.ses = ses
                .iter()
                .map(|s| {
                    Ok(SeConfig {
                        name: s
                            .get("name")
                            .and_then(Json::as_str)
                            .ok_or_else(|| Error::Config("se missing name".into()))?
                            .to_string(),
                        region: s
                            .get("region")
                            .and_then(Json::as_str)
                            .unwrap_or("unknown")
                            .to_string(),
                        endpoint: s
                            .get("endpoint")
                            .and_then(Json::as_str)
                            .filter(|e| !e.is_empty())
                            .map(str::to_string),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(n) = j.get("network") {
            let mut p = NetworkProfile::paper_testbed();
            if let Some(v) = n.get("setup_s").and_then(Json::as_f64) {
                p.setup_s = v;
            }
            if let Some(v) = n.get("bandwidth_bps").and_then(Json::as_f64) {
                p.bandwidth_bps = v;
            }
            if let Some(v) = n.get("congestion_alpha").and_then(Json::as_f64) {
                p.congestion_alpha = v;
            }
            if let Some(v) = n.get("jitter_frac").and_then(Json::as_f64) {
                p.jitter_frac = v;
            }
            cfg.network = Some(p);
        }
        Ok(cfg)
    }

    /// Serialize to the `drs.json` form.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("vo", Json::str(self.vo.clone())),
            (
                "ec",
                Json::obj(vec![
                    ("k", Json::num(self.params.k() as f64)),
                    ("m", Json::num(self.params.m() as f64)),
                    ("stripe_b", Json::num(self.stripe_b as f64)),
                ]),
            ),
            ("ec_backend", Json::str(self.ec_backend.as_str())),
            ("placement", Json::str(self.policy.as_str())),
            ("client_region", Json::str(self.client_region.clone())),
            ("workers", Json::num(self.workers as f64)),
            ("transfer_block_bytes", Json::num(self.transfer_block_bytes as f64)),
            ("cache_bytes", Json::num(self.cache_bytes as f64)),
            ("cache_degraded_bytes", Json::num(self.cache_degraded_bytes as f64)),
            ("catalog_shards", Json::num(self.catalog_shards as f64)),
            ("journal_segment_bytes", Json::num(self.journal_segment_bytes as f64)),
            ("journal_checkpoint_ops", Json::num(self.journal_checkpoint_ops as f64)),
            ("maintain_scrub_interval_s", Json::Num(self.maintain_scrub_interval_s)),
            ("maintain_scrub_slice", Json::num(self.maintain_scrub_slice as f64)),
            ("maintain_deep_every", Json::num(self.maintain_deep_every as f64)),
            (
                "maintain_repair_budget_files",
                Json::num(self.maintain_repair_budget_files as f64),
            ),
            ("maintain_repair_budget_mb", Json::num(self.maintain_repair_budget_mb as f64)),
            ("obs_trace", Json::Bool(self.obs_trace)),
            ("obs_trace_buffer", Json::num(self.obs_trace_buffer as f64)),
            ("obs_trace_file_bytes", Json::num(self.obs_trace_file_bytes as f64)),
            ("obs_status_addr", Json::str(self.obs_status_addr.clone())),
            (
                "maintain_drain_after_passes",
                Json::num(self.maintain_drain_after_passes as f64),
            ),
            ("remote_connect_timeout_ms", Json::num(self.remote_connect_timeout_ms as f64)),
            ("remote_io_timeout_ms", Json::num(self.remote_io_timeout_ms as f64)),
            ("remote_pool_max_idle", Json::num(self.remote_pool_max_idle as f64)),
            ("remote_pool_idle_secs", Json::num(self.remote_pool_idle_secs as f64)),
            ("remote_pipeline_window", Json::num(self.remote_pipeline_window as f64)),
            (
                "ses",
                Json::Arr(
                    self.ses
                        .iter()
                        .map(|s| {
                            let mut pairs = vec![
                                ("name", Json::str(s.name.clone())),
                                ("region", Json::str(s.region.clone())),
                            ];
                            if let Some(e) = &s.endpoint {
                                pairs.push(("endpoint", Json::str(e.clone())));
                            }
                            Json::obj(pairs)
                        })
                        .collect(),
                ),
            ),
        ];
        if let Some(n) = &self.network {
            pairs.push((
                "network",
                Json::obj(vec![
                    ("setup_s", Json::Num(n.setup_s)),
                    ("bandwidth_bps", Json::Num(n.bandwidth_bps)),
                    ("congestion_alpha", Json::Num(n.congestion_alpha)),
                    ("jitter_frac", Json::Num(n.jitter_frac)),
                ]),
            ));
        }
        Json::obj(pairs)
    }

    /// Load from a file, then apply `DRS_*` environment overrides.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| Error::Config(format!("{e}")))?;
        let mut cfg = Self::from_json(&j)?;
        cfg.apply_env();
        Ok(cfg)
    }

    /// Write the config to a file (crash-safe: temp file + rename).
    pub fn save(&self, path: &Path) -> Result<()> {
        crate::util::atomic_write(path, self.to_json().to_string().as_bytes())
    }

    /// The catalogue journal tuning this config describes.
    pub fn journal(&self) -> crate::catalog::JournalConfig {
        crate::catalog::JournalConfig {
            segment_bytes: self.journal_segment_bytes.max(1),
            checkpoint_ops: self.journal_checkpoint_ops.max(1),
        }
    }

    /// Apply environment overrides: `DRS_VO`, `DRS_WORKERS`, `DRS_K`,
    /// `DRS_M`, `DRS_STRIPE_B`, `DRS_EC_BACKEND`, `DRS_PLACEMENT`,
    /// `DRS_CLIENT_REGION`, `DRS_TRANSFER_BLOCK_BYTES`,
    /// `DRS_CACHE_BYTES`, `DRS_CACHE_DEGRADED_BYTES`,
    /// `DRS_CATALOG_SHARDS`,
    /// `DRS_JOURNAL_SEGMENT_BYTES`, `DRS_JOURNAL_CHECKPOINT_OPS`,
    /// `DRS_MAINTAIN_SCRUB_INTERVAL_S`, `DRS_MAINTAIN_SCRUB_SLICE`,
    /// `DRS_MAINTAIN_DEEP_EVERY`, `DRS_MAINTAIN_REPAIR_BUDGET_FILES`,
    /// `DRS_MAINTAIN_REPAIR_BUDGET_MB`, `DRS_OBS_TRACE`,
    /// `DRS_OBS_TRACE_BUFFER`, `DRS_OBS_TRACE_FILE_BYTES`,
    /// `DRS_OBS_STATUS_ADDR`, `DRS_MAINTAIN_DRAIN_AFTER_PASSES`,
    /// `DRS_REMOTE_CONNECT_TIMEOUT_MS`, `DRS_REMOTE_IO_TIMEOUT_MS`,
    /// `DRS_REMOTE_POOL_MAX_IDLE`, `DRS_REMOTE_POOL_IDLE_SECS`,
    /// `DRS_REMOTE_PIPELINE_WINDOW`.
    pub fn apply_env(&mut self) {
        if let Ok(n) = std::env::var("DRS_MAINTAIN_DRAIN_AFTER_PASSES") {
            if let Ok(n) = n.parse::<u64>() {
                self.maintain_drain_after_passes = n;
            }
        }
        if let Ok(n) = std::env::var("DRS_REMOTE_CONNECT_TIMEOUT_MS") {
            if let Ok(n) = n.parse::<u64>() {
                self.remote_connect_timeout_ms = n.max(1);
            }
        }
        if let Ok(n) = std::env::var("DRS_REMOTE_IO_TIMEOUT_MS") {
            if let Ok(n) = n.parse::<u64>() {
                self.remote_io_timeout_ms = n.max(1);
            }
        }
        if let Ok(n) = std::env::var("DRS_REMOTE_POOL_MAX_IDLE") {
            if let Ok(n) = n.parse::<usize>() {
                self.remote_pool_max_idle = n;
            }
        }
        if let Ok(n) = std::env::var("DRS_REMOTE_POOL_IDLE_SECS") {
            if let Ok(n) = n.parse::<u64>() {
                self.remote_pool_idle_secs = n.max(1);
            }
        }
        if let Ok(n) = std::env::var("DRS_REMOTE_PIPELINE_WINDOW") {
            if let Ok(n) = n.parse::<usize>() {
                self.remote_pipeline_window = n.max(1);
            }
        }
        if let Ok(v) = std::env::var("DRS_OBS_TRACE") {
            // Accept the usual boolean spellings; anything else is off.
            self.obs_trace = matches!(v.as_str(), "1" | "true" | "yes" | "on");
        }
        if let Ok(n) = std::env::var("DRS_OBS_TRACE_BUFFER") {
            if let Ok(n) = n.parse::<usize>() {
                self.obs_trace_buffer = n.max(1);
            }
        }
        if let Ok(n) = std::env::var("DRS_OBS_TRACE_FILE_BYTES") {
            if let Ok(n) = n.parse::<u64>() {
                self.obs_trace_file_bytes = n.max(1);
            }
        }
        if let Ok(a) = std::env::var("DRS_OBS_STATUS_ADDR") {
            self.obs_status_addr = a;
        }
        if let Ok(s) = std::env::var("DRS_MAINTAIN_SCRUB_INTERVAL_S") {
            if let Ok(s) = s.parse::<f64>() {
                self.maintain_scrub_interval_s = s.max(0.0);
            }
        }
        if let Ok(n) = std::env::var("DRS_MAINTAIN_SCRUB_SLICE") {
            if let Ok(n) = n.parse::<usize>() {
                self.maintain_scrub_slice = n;
            }
        }
        if let Ok(n) = std::env::var("DRS_MAINTAIN_DEEP_EVERY") {
            if let Ok(n) = n.parse::<u64>() {
                self.maintain_deep_every = n;
            }
        }
        if let Ok(n) = std::env::var("DRS_MAINTAIN_REPAIR_BUDGET_FILES") {
            if let Ok(n) = n.parse::<usize>() {
                self.maintain_repair_budget_files = n;
            }
        }
        if let Ok(n) = std::env::var("DRS_MAINTAIN_REPAIR_BUDGET_MB") {
            if let Ok(n) = n.parse::<u64>() {
                self.maintain_repair_budget_mb = n;
            }
        }
        if let Ok(b) = std::env::var("DRS_CACHE_BYTES") {
            if let Ok(b) = b.parse::<u64>() {
                self.cache_bytes = b;
            }
        }
        if let Ok(b) = std::env::var("DRS_CACHE_DEGRADED_BYTES") {
            if let Ok(b) = b.parse::<u64>() {
                self.cache_degraded_bytes = b;
            }
        }
        if let Ok(s) = std::env::var("DRS_CATALOG_SHARDS") {
            if let Ok(s) = s.parse::<usize>() {
                self.catalog_shards = s.max(1);
            }
        }
        if let Ok(b) = std::env::var("DRS_JOURNAL_SEGMENT_BYTES") {
            if let Ok(b) = b.parse::<u64>() {
                self.journal_segment_bytes = b.max(1);
            }
        }
        if let Ok(n) = std::env::var("DRS_JOURNAL_CHECKPOINT_OPS") {
            if let Ok(n) = n.parse::<u64>() {
                self.journal_checkpoint_ops = n.max(1);
            }
        }
        if let Ok(vo) = std::env::var("DRS_VO") {
            self.vo = vo;
        }
        if let Ok(w) = std::env::var("DRS_WORKERS") {
            if let Ok(w) = w.parse::<usize>() {
                self.workers = w.max(1);
            }
        }
        if let Ok(b) = std::env::var("DRS_TRANSFER_BLOCK_BYTES") {
            if let Ok(b) = b.parse::<usize>() {
                self.transfer_block_bytes = b.max(1);
            }
        }
        let k = std::env::var("DRS_K").ok().and_then(|v| v.parse().ok());
        let m = std::env::var("DRS_M").ok().and_then(|v| v.parse().ok());
        if k.is_some() || m.is_some() {
            if let Ok(p) =
                EcParams::new(k.unwrap_or(self.params.k()), m.unwrap_or(self.params.m()))
            {
                self.params = p;
            }
        }
        if let Ok(sb) = std::env::var("DRS_STRIPE_B") {
            if let Ok(sb) = sb.parse::<usize>() {
                self.stripe_b = sb.max(1);
            }
        }
        if let Ok(b) = std::env::var("DRS_EC_BACKEND") {
            if let Ok(b) = BackendChoice::parse(&b) {
                self.ec_backend = b;
            }
        }
        if let Ok(p) = std::env::var("DRS_PLACEMENT") {
            if let Ok(p) = PolicyKind::parse(&p) {
                self.policy = p;
            }
        }
        if let Ok(r) = std::env::var("DRS_CLIENT_REGION") {
            self.client_region = r;
        }
    }

    /// The [`crate::se::RemoteOptions`] this config's `remote_*` knobs
    /// describe — what the workspace hands to every [`crate::se::RemoteSe`]
    /// it builds for an `endpoint`-bearing SE entry.
    pub fn remote_options(&self) -> crate::se::RemoteOptions {
        let mut o = crate::se::RemoteOptions::default();
        o.connect_timeout = std::time::Duration::from_millis(self.remote_connect_timeout_ms);
        o.io_timeout = std::time::Duration::from_millis(self.remote_io_timeout_ms);
        o.pool_max_idle = self.remote_pool_max_idle;
        o.pool_idle = std::time::Duration::from_secs(self.remote_pool_idle_secs);
        o.pipeline_window = self.remote_pipeline_window.max(1);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_geometry() {
        let c = Config::default();
        assert_eq!(c.params, EcParams::new(10, 5).unwrap());
        assert_eq!(c.ses.len(), 15);
        assert_eq!(c.policy, PolicyKind::RoundRobin);
    }

    #[test]
    fn json_roundtrip() {
        let mut c = Config::default();
        c.vo = "na62".into();
        c.network = Some(NetworkProfile::paper_testbed());
        c.policy = PolicyKind::RegionAware;
        c.catalog_shards = 4;
        let j = c.to_json();
        let back = Config::from_json(&j).unwrap();
        assert_eq!(back.vo, "na62");
        assert_eq!(back.policy, PolicyKind::RegionAware);
        assert_eq!(back.ses, c.ses);
        assert_eq!(back.catalog_shards, 4);
        assert!((back.network.unwrap().setup_s - 5.5).abs() < 1e-12);
    }

    #[test]
    fn transfer_block_bytes_roundtrip_env_and_default() {
        // Old configs (no transfer_block_bytes key) get the default.
        let j = Json::parse(r#"{"vo":"demo"}"#).unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.transfer_block_bytes, crate::dfm::DEFAULT_TRANSFER_BLOCK_BYTES);

        let mut c = Config::default();
        c.transfer_block_bytes = 1 << 20;
        let back = Config::from_json(&c.to_json()).unwrap();
        assert_eq!(back.transfer_block_bytes, 1 << 20);

        let mut c = Config::default();
        std::env::set_var("DRS_TRANSFER_BLOCK_BYTES", "65536");
        c.apply_env();
        std::env::remove_var("DRS_TRANSFER_BLOCK_BYTES");
        assert_eq!(c.transfer_block_bytes, 65536);
    }

    #[test]
    fn ec_backend_roundtrip_env_and_default() {
        // Old configs (no ec_backend key) get runtime auto-selection.
        let c = Config::from_json(&Json::parse(r#"{"vo":"demo"}"#).unwrap()).unwrap();
        assert_eq!(c.ec_backend, BackendChoice::Auto);

        let c = Config { ec_backend: BackendChoice::Scalar, ..Config::default() };
        let back = Config::from_json(&c.to_json()).unwrap();
        assert_eq!(back.ec_backend, BackendChoice::Scalar);

        // A bad knob value is a hard config error, not a silent default.
        let j = Json::parse(r#"{"ec_backend":"neon"}"#).unwrap();
        assert!(Config::from_json(&j).is_err());

        let mut c = Config::default();
        std::env::set_var("DRS_EC_BACKEND", "ssse3");
        c.apply_env();
        std::env::remove_var("DRS_EC_BACKEND");
        assert_eq!(c.ec_backend, BackendChoice::Ssse3);
    }

    #[test]
    fn catalog_shards_defaults_when_absent() {
        // Old configs (no catalog_shards key) keep working.
        let j = Json::parse(r#"{"vo":"demo"}"#).unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.catalog_shards, crate::catalog::DEFAULT_SHARDS);
        assert_eq!(c.journal_segment_bytes, crate::catalog::DEFAULT_SEGMENT_BYTES);
        assert_eq!(c.journal_checkpoint_ops, crate::catalog::DEFAULT_CHECKPOINT_OPS);
    }

    #[test]
    fn journal_knobs_roundtrip_and_env() {
        let mut c = Config::default();
        c.journal_segment_bytes = 4096;
        c.journal_checkpoint_ops = 32;
        let back = Config::from_json(&c.to_json()).unwrap();
        assert_eq!(back.journal_segment_bytes, 4096);
        assert_eq!(back.journal_checkpoint_ops, 32);
        assert_eq!(back.journal(), crate::catalog::JournalConfig {
            segment_bytes: 4096,
            checkpoint_ops: 32
        });

        let mut c = Config::default();
        std::env::set_var("DRS_JOURNAL_SEGMENT_BYTES", "65536");
        std::env::set_var("DRS_JOURNAL_CHECKPOINT_OPS", "7");
        c.apply_env();
        std::env::remove_var("DRS_JOURNAL_SEGMENT_BYTES");
        std::env::remove_var("DRS_JOURNAL_CHECKPOINT_OPS");
        assert_eq!(c.journal_segment_bytes, 65536);
        assert_eq!(c.journal_checkpoint_ops, 7);
    }

    #[test]
    fn maintain_knobs_roundtrip_env_and_defaults() {
        // Old configs (no maintain_* keys) get the defaults.
        let c = Config::from_json(&Json::parse(r#"{"vo":"demo"}"#).unwrap()).unwrap();
        assert!((c.maintain_scrub_interval_s - 30.0).abs() < 1e-12);
        assert_eq!(c.maintain_scrub_slice, 64);
        assert_eq!(c.maintain_deep_every, 4);
        assert_eq!(c.maintain_repair_budget_files, 0);
        assert_eq!(c.maintain_repair_budget_mb, 0);

        let mut c = Config::default();
        c.maintain_scrub_interval_s = 2.5;
        c.maintain_scrub_slice = 10;
        c.maintain_deep_every = 7;
        c.maintain_repair_budget_files = 3;
        c.maintain_repair_budget_mb = 128;
        let back = Config::from_json(&c.to_json()).unwrap();
        assert!((back.maintain_scrub_interval_s - 2.5).abs() < 1e-12);
        assert_eq!(back.maintain_scrub_slice, 10);
        assert_eq!(back.maintain_deep_every, 7);
        assert_eq!(back.maintain_repair_budget_files, 3);
        assert_eq!(back.maintain_repair_budget_mb, 128);

        let mut c = Config::default();
        std::env::set_var("DRS_MAINTAIN_SCRUB_INTERVAL_S", "0.25");
        std::env::set_var("DRS_MAINTAIN_SCRUB_SLICE", "5");
        std::env::set_var("DRS_MAINTAIN_DEEP_EVERY", "2");
        std::env::set_var("DRS_MAINTAIN_REPAIR_BUDGET_FILES", "9");
        std::env::set_var("DRS_MAINTAIN_REPAIR_BUDGET_MB", "77");
        c.apply_env();
        std::env::remove_var("DRS_MAINTAIN_SCRUB_INTERVAL_S");
        std::env::remove_var("DRS_MAINTAIN_SCRUB_SLICE");
        std::env::remove_var("DRS_MAINTAIN_DEEP_EVERY");
        std::env::remove_var("DRS_MAINTAIN_REPAIR_BUDGET_FILES");
        std::env::remove_var("DRS_MAINTAIN_REPAIR_BUDGET_MB");
        assert!((c.maintain_scrub_interval_s - 0.25).abs() < 1e-12);
        assert_eq!(c.maintain_scrub_slice, 5);
        assert_eq!(c.maintain_deep_every, 2);
        assert_eq!(c.maintain_repair_budget_files, 9);
        assert_eq!(c.maintain_repair_budget_mb, 77);
    }

    #[test]
    fn obs_knobs_roundtrip_env_and_defaults() {
        // Old configs (no obs_* keys) get the defaults: tracing off.
        let c = Config::from_json(&Json::parse(r#"{"vo":"demo"}"#).unwrap()).unwrap();
        assert!(!c.obs_trace);
        assert_eq!(c.obs_trace_buffer, crate::obs::DEFAULT_BUFFER_SPANS);
        assert_eq!(c.obs_trace_file_bytes, 4 << 20);
        assert_eq!(c.obs_status_addr, "");

        let mut c = Config::default();
        c.obs_trace = true;
        c.obs_trace_buffer = 512;
        c.obs_trace_file_bytes = 1 << 20;
        c.obs_status_addr = "127.0.0.1:9632".into();
        let back = Config::from_json(&c.to_json()).unwrap();
        assert!(back.obs_trace);
        assert_eq!(back.obs_trace_buffer, 512);
        assert_eq!(back.obs_trace_file_bytes, 1 << 20);
        assert_eq!(back.obs_status_addr, "127.0.0.1:9632");

        let mut c = Config::default();
        std::env::set_var("DRS_OBS_TRACE", "on");
        std::env::set_var("DRS_OBS_TRACE_BUFFER", "64");
        std::env::set_var("DRS_OBS_TRACE_FILE_BYTES", "4096");
        std::env::set_var("DRS_OBS_STATUS_ADDR", "0.0.0.0:8080");
        c.apply_env();
        std::env::remove_var("DRS_OBS_TRACE");
        std::env::remove_var("DRS_OBS_TRACE_BUFFER");
        std::env::remove_var("DRS_OBS_TRACE_FILE_BYTES");
        std::env::remove_var("DRS_OBS_STATUS_ADDR");
        assert!(c.obs_trace);
        assert_eq!(c.obs_trace_buffer, 64);
        assert_eq!(c.obs_trace_file_bytes, 4096);
        assert_eq!(c.obs_status_addr, "0.0.0.0:8080");
        // Unrecognized boolean spellings turn tracing off, not on.
        std::env::set_var("DRS_OBS_TRACE", "maybe");
        c.apply_env();
        std::env::remove_var("DRS_OBS_TRACE");
        assert!(!c.obs_trace);
    }

    #[test]
    fn cache_knobs_roundtrip_env_and_defaults() {
        // Old configs (no cache_* keys) get the defaults.
        let c = Config::from_json(&Json::parse(r#"{"vo":"demo"}"#).unwrap()).unwrap();
        assert_eq!(c.cache_bytes, 256 << 20);
        assert_eq!(c.cache_degraded_bytes, 64 << 20);

        let mut c = Config::default();
        c.cache_bytes = 1 << 20;
        c.cache_degraded_bytes = 0; // explicit 0 = disabled, must survive
        let back = Config::from_json(&c.to_json()).unwrap();
        assert_eq!(back.cache_bytes, 1 << 20);
        assert_eq!(back.cache_degraded_bytes, 0);

        let mut c = Config::default();
        std::env::set_var("DRS_CACHE_BYTES", "4096");
        std::env::set_var("DRS_CACHE_DEGRADED_BYTES", "1024");
        c.apply_env();
        std::env::remove_var("DRS_CACHE_BYTES");
        std::env::remove_var("DRS_CACHE_DEGRADED_BYTES");
        assert_eq!(c.cache_bytes, 4096);
        assert_eq!(c.cache_degraded_bytes, 1024);
    }

    #[test]
    fn parse_example_doc() {
        let j = Json::parse(
            r#"{"vo":"na62","ec":{"k":8,"m":2,"stripe_b":16384},
                "placement":"weighted","workers":4,
                "ses":[{"name":"A","region":"uk"},{"name":"B"}]}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.params, EcParams::new(8, 2).unwrap());
        assert_eq!(c.stripe_b, 16384);
        assert_eq!(c.workers, 4);
        assert_eq!(c.ses[1].region, "unknown");
    }

    #[test]
    fn bad_policy_rejected() {
        assert!(PolicyKind::parse("chaos").is_err());
        for p in ["round-robin", "random", "weighted", "region-aware"] {
            assert_eq!(PolicyKind::parse(p).unwrap().as_str(), p);
        }
    }

    #[test]
    fn policy_builds() {
        use crate::se::SeInfo;
        let infos: Vec<SeInfo> = (0..4)
            .map(|i| SeInfo {
                name: format!("S{i}"),
                region: "uk".into(),
                available: true,
                used_bytes: 0,
            })
            .collect();
        for kind in [
            PolicyKind::RoundRobin,
            PolicyKind::Random,
            PolicyKind::Weighted,
            PolicyKind::RegionAware,
        ] {
            let p = kind.build("uk", 4);
            assert_eq!(p.place(6, &infos).unwrap().len(), 6);
        }
    }

    #[test]
    fn env_overrides() {
        let mut c = Config::default();
        std::env::set_var("DRS_WORKERS", "7");
        std::env::set_var("DRS_K", "6");
        std::env::set_var("DRS_M", "3");
        std::env::set_var("DRS_CLIENT_REGION", "fr");
        c.apply_env();
        std::env::remove_var("DRS_WORKERS");
        std::env::remove_var("DRS_K");
        std::env::remove_var("DRS_M");
        std::env::remove_var("DRS_CLIENT_REGION");
        assert_eq!(c.workers, 7);
        assert_eq!(c.params, EcParams::new(6, 3).unwrap());
        assert_eq!(c.client_region, "fr");
    }

    #[test]
    fn remote_knobs_roundtrip_env_and_default() {
        // Old configs (no remote_* keys) get the defaults.
        let j = Json::parse(r#"{"vo":"demo"}"#).unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.maintain_drain_after_passes, 0);
        assert_eq!(c.remote_connect_timeout_ms, 5_000);
        assert_eq!(c.remote_io_timeout_ms, 30_000);
        assert_eq!(c.remote_pool_max_idle, 4);
        assert_eq!(c.remote_pool_idle_secs, 60);
        assert_eq!(c.remote_pipeline_window, 4);

        // JSON round-trip preserves explicit values.
        let mut c = Config::default();
        c.maintain_drain_after_passes = 3;
        c.remote_connect_timeout_ms = 1_500;
        c.remote_io_timeout_ms = 9_000;
        c.remote_pool_max_idle = 2;
        c.remote_pool_idle_secs = 11;
        c.remote_pipeline_window = 8;
        let back = Config::from_json(&c.to_json()).unwrap();
        assert_eq!(back.maintain_drain_after_passes, 3);
        assert_eq!(back.remote_connect_timeout_ms, 1_500);
        assert_eq!(back.remote_io_timeout_ms, 9_000);
        assert_eq!(back.remote_pool_max_idle, 2);
        assert_eq!(back.remote_pool_idle_secs, 11);
        assert_eq!(back.remote_pipeline_window, 8);

        // Env overrides win; a zero pipeline window clamps to 1.
        let mut c = Config::default();
        std::env::set_var("DRS_MAINTAIN_DRAIN_AFTER_PASSES", "5");
        std::env::set_var("DRS_REMOTE_CONNECT_TIMEOUT_MS", "250");
        std::env::set_var("DRS_REMOTE_IO_TIMEOUT_MS", "750");
        std::env::set_var("DRS_REMOTE_POOL_MAX_IDLE", "0");
        std::env::set_var("DRS_REMOTE_POOL_IDLE_SECS", "7");
        std::env::set_var("DRS_REMOTE_PIPELINE_WINDOW", "0");
        c.apply_env();
        std::env::remove_var("DRS_MAINTAIN_DRAIN_AFTER_PASSES");
        std::env::remove_var("DRS_REMOTE_CONNECT_TIMEOUT_MS");
        std::env::remove_var("DRS_REMOTE_IO_TIMEOUT_MS");
        std::env::remove_var("DRS_REMOTE_POOL_MAX_IDLE");
        std::env::remove_var("DRS_REMOTE_POOL_IDLE_SECS");
        std::env::remove_var("DRS_REMOTE_PIPELINE_WINDOW");
        assert_eq!(c.maintain_drain_after_passes, 5);
        assert_eq!(c.remote_connect_timeout_ms, 250);
        assert_eq!(c.remote_pool_max_idle, 0);
        assert_eq!(c.remote_pipeline_window, 1);

        let o = c.remote_options();
        assert_eq!(o.connect_timeout, std::time::Duration::from_millis(250));
        assert_eq!(o.io_timeout, std::time::Duration::from_millis(750));
        assert_eq!(o.pool_max_idle, 0);
        assert_eq!(o.pool_idle, std::time::Duration::from_secs(7));
        assert_eq!(o.pipeline_window, 1);
    }

    #[test]
    fn se_endpoint_roundtrips_and_defaults_to_none() {
        // Absent key → local SE.
        let j = Json::parse(r#"{"ses":[{"name":"A","region":"uk"}]}"#).unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.ses[0].endpoint, None);

        // Explicit endpoint survives a round-trip; empty string is None.
        let j = Json::parse(
            r#"{"ses":[{"name":"A","region":"uk","endpoint":"127.0.0.1:7070"},
                       {"name":"B","region":"fr","endpoint":""}]}"#,
        )
        .unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.ses[0].endpoint.as_deref(), Some("127.0.0.1:7070"));
        assert_eq!(c.ses[1].endpoint, None);
        let back = Config::from_json(&c.to_json()).unwrap();
        assert_eq!(back.ses, c.ses);
    }
}
