//! Lightweight property-testing support (proptest is unavailable offline).
//!
//! `forall(n, |rng| ...)` runs the closure `n` times with independently
//! seeded deterministic RNGs. On panic the failing case's seed is printed
//! so the case can be replayed with `replay(seed, ...)`. This loses
//! proptest's shrinking but keeps the two properties that matter for CI:
//! deterministic replay and coverage across many random cases.

use crate::util::prng::Rng;

/// Base seed; change DRS_PROP_SEED to explore a different universe.
fn base_seed() -> u64 {
    std::env::var("DRS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD15C_0000)
}

/// Run `f` against `cases` independently seeded RNGs.
///
/// Panics (re-raising the inner panic) with the failing seed in the message.
pub fn forall<F: Fn(&mut Rng)>(cases: u64, f: F) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            f(&mut rng);
        }));
        if let Err(payload) = result {
            eprintln!(
                "property failed at case {case}/{cases}, seed {seed:#x} \
                 (replay with drs::testkit::replay({seed:#x}, ...))"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F: Fn(&mut Rng)>(seed: u64, f: F) {
    let mut rng = Rng::new(seed);
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let count = std::cell::Cell::new(0u64);
        forall(17, |_| count.set(count.get() + 1));
        assert_eq!(count.get(), 17);
    }

    #[test]
    fn forall_cases_get_distinct_randomness() {
        let mut seen = std::collections::HashSet::new();
        let seen_ref = std::cell::RefCell::new(&mut seen);
        forall(10, |rng| {
            seen_ref.borrow_mut().insert(rng.next_u64());
        });
        assert_eq!(seen.len(), 10);
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failures() {
        forall(5, |rng| assert!(rng.f64() < 0.5, "intentional"));
    }
}
