//! Lightweight property-testing support (proptest is unavailable offline).
//!
//! `forall(n, |rng| ...)` runs the closure `n` times with independently
//! seeded deterministic RNGs. On panic the failing case's seed is printed
//! so the case can be replayed with `replay(seed, ...)`. This loses
//! proptest's shrinking but keeps the two properties that matter for CI:
//! deterministic replay and coverage across many random cases.
//!
//! Also here: [`FaultProxy`], a TCP fault injector for exercising the
//! remote-SE transport (`se::remote` / `se::server`) under network
//! misbehaviour — dropped endpoints, added latency, torn frames and
//! stalled responses — without touching the protocol code itself.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::prng::Rng;
use crate::Result;

/// Base seed; change DRS_PROP_SEED to explore a different universe.
fn base_seed() -> u64 {
    std::env::var("DRS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD15C_0000)
}

/// Run `f` against `cases` independently seeded RNGs.
///
/// Panics (re-raising the inner panic) with the failing seed in the message.
pub fn forall<F: Fn(&mut Rng)>(cases: u64, f: F) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            f(&mut rng);
        }));
        if let Err(payload) = result {
            eprintln!(
                "property failed at case {case}/{cases}, seed {seed:#x} \
                 (replay with drs::testkit::replay({seed:#x}, ...))"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F: Fn(&mut Rng)>(seed: u64, f: F) {
    let mut rng = Rng::new(seed);
    f(&mut rng);
}

/// What a [`FaultProxy`] does to traffic. Settable at runtime, so one
/// proxy can serve a clean warm-up phase and then turn hostile — which
/// is exactly how the remote-SE failover tests use it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fault {
    /// Forward faithfully.
    None,
    /// Close every new connection immediately and tear existing ones on
    /// their next relayed buffer (a dark / refused endpoint).
    Drop,
    /// Sleep this long before relaying each buffer (a slow link).
    Delay(Duration),
    /// Relay this many more server→client bytes, then tear the
    /// connection — the client sees a torn frame mid-response.
    TruncateAfter(u64),
    /// Keep accepting client→server traffic but never relay a response;
    /// the client's read deadline is what ends the wait.
    Stall,
}

/// A TCP proxy that forwards to one upstream address and injects the
/// currently-set [`Fault`]. Listens on an ephemeral loopback port;
/// point a `RemoteSe` endpoint at [`FaultProxy::addr`] and the real
/// chunk server at the upstream.
pub struct FaultProxy {
    addr: SocketAddr,
    mode: Arc<Mutex<Fault>>,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
}

/// How often pump threads wake to re-check the fault mode / stop flag.
const PUMP_TICK: Duration = Duration::from_millis(5);

impl FaultProxy {
    /// Start a proxy in front of `upstream`.
    pub fn start(upstream: SocketAddr) -> Result<FaultProxy> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let mode = Arc::new(Mutex::new(Fault::None));
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let mode = Arc::clone(&mode);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                for client in listener.incoming() {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let client = match client {
                        Ok(c) => c,
                        Err(_) => break,
                    };
                    if *crate::util::lock(&mode) == Fault::Drop {
                        continue; // dropping the socket closes it
                    }
                    let server = match TcpStream::connect(upstream) {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    spawn_pumps(client, server, Arc::clone(&mode), Arc::clone(&stop));
                }
            })
        };
        Ok(FaultProxy { addr, mode, stop, accept: Some(accept) })
    }

    /// The address clients should dial.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Swap the active fault. Applies to new connections immediately and
    /// to live ones on their next relayed buffer.
    pub fn set(&self, fault: Fault) {
        *crate::util::lock(&self.mode) = fault;
    }

    /// Stop the proxy (all pump threads wind down on their next tick).
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for FaultProxy {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.shutdown();
        }
    }
}

fn spawn_pumps(
    client: TcpStream,
    server: TcpStream,
    mode: Arc<Mutex<Fault>>,
    stop: Arc<AtomicBool>,
) {
    let (c2, s2) = match (client.try_clone(), server.try_clone()) {
        (Ok(c), Ok(s)) => (c, s),
        _ => return,
    };
    {
        let mode = Arc::clone(&mode);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || pump(client, s2, &mode, &stop, false));
    }
    std::thread::spawn(move || pump(server, c2, &mode, &stop, true));
}

/// Relay `from` → `to` until EOF, error, stop, or an injected tear.
/// `is_response` marks the server→client direction, the one Stall and
/// TruncateAfter act on (requests always flow, like a link whose return
/// path is sick).
fn pump(
    from: TcpStream,
    to: TcpStream,
    mode: &Mutex<Fault>,
    stop: &AtomicBool,
    is_response: bool,
) {
    let mut from = from;
    let mut to = to;
    if from.set_read_timeout(Some(PUMP_TICK)).is_err() {
        return;
    }
    let mut buf = [0u8; 8 << 10];
    // Bytes relayed since TruncateAfter was last activated.
    let mut truncated_budget_used = 0u64;
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let fault = *crate::util::lock(mode);
        if !matches!(fault, Fault::TruncateAfter(_)) {
            truncated_budget_used = 0;
        }
        match fault {
            Fault::Drop => {
                // Tear both halves; the client sees a reset/EOF.
                let _ = from.shutdown(std::net::Shutdown::Both);
                let _ = to.shutdown(std::net::Shutdown::Both);
                return;
            }
            Fault::Stall if is_response => {
                // Leave the bytes queued in the kernel; the client's
                // read deadline does the failing.
                std::thread::sleep(PUMP_TICK);
                continue;
            }
            _ => {}
        }
        let n = match from.read(&mut buf) {
            Ok(0) => {
                let _ = to.shutdown(std::net::Shutdown::Write);
                return;
            }
            Ok(n) => n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => {
                let _ = to.shutdown(std::net::Shutdown::Both);
                return;
            }
        };
        let send = &buf[..n];
        match fault {
            Fault::Delay(d) => std::thread::sleep(d),
            Fault::TruncateAfter(limit) if is_response => {
                let left = limit.saturating_sub(truncated_budget_used) as usize;
                if left < send.len() {
                    // Forward the allowed prefix, then tear mid-frame.
                    let _ = to.write_all(&send[..left]);
                    let _ = from.shutdown(std::net::Shutdown::Both);
                    let _ = to.shutdown(std::net::Shutdown::Both);
                    return;
                }
                truncated_budget_used += send.len() as u64;
            }
            _ => {}
        }
        if to.write_all(send).is_err() {
            let _ = from.shutdown(std::net::Shutdown::Both);
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let count = std::cell::Cell::new(0u64);
        forall(17, |_| count.set(count.get() + 1));
        assert_eq!(count.get(), 17);
    }

    #[test]
    fn forall_cases_get_distinct_randomness() {
        let mut seen = std::collections::HashSet::new();
        let seen_ref = std::cell::RefCell::new(&mut seen);
        forall(10, |rng| {
            seen_ref.borrow_mut().insert(rng.next_u64());
        });
        assert_eq!(seen.len(), 10);
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failures() {
        forall(5, |rng| assert!(rng.f64() < 0.5, "intentional"));
    }
}
