//! `drs lint` — an in-repo, dependency-free static analyzer for the
//! crate's own invariants.
//!
//! The analyzer lexes every file under `rust/src` with a hand-rolled
//! masking lexer ([`lexer`]) so rule passes never match inside string
//! literals, char literals or comments, then runs six rule passes
//! ([`rules`]):
//!
//! | id | key | invariant |
//! |----|-----|-----------|
//! | R1 | `panic` | no `unwrap`/`expect`/`panic!`-family in non-test library code |
//! | R2 | `unsafe` | `// SAFETY:` before every `unsafe`, `# Safety` docs on `unsafe fn` |
//! | R3 | `lock` | nested `.lock()`s follow [`lock_order`]; `.lock().unwrap()` flagged |
//! | R4 | `knob` | config fields ↔ `DRS_*` env bindings ↔ doc tables, both directions |
//! | R5 | `metric` | metric/span name literals documented + convention-clean |
//! | R6 | `atomic-write` | no raw `fs::write`/`File::create` outside `util::atomic_write` |
//!
//! Findings are compared against the committed `lint_baseline.json`
//! ([`baseline`]): only *regressions* (a (rule, file) count above the
//! baseline) fail, and the baseline itself can only shrink. See
//! `docs/STATIC_ANALYSIS.md` for the operator guide.

pub mod baseline;
pub mod lexer;
pub mod lock_order;
pub mod rules;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::{Error, Result};

use baseline::Baseline;

/// The six lint rules. `key()` is the toggle / allow-comment name,
/// `id()` the stable short id used in output and the baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// R1 — panic-freedom in library code.
    Panic,
    /// R2 — `SAFETY:` / `# Safety` hygiene on `unsafe`.
    Unsafe,
    /// R3 — declared lock order + poisoning-cascade sites.
    Lock,
    /// R4 — config knob ↔ env ↔ docs drift.
    Knob,
    /// R5 — metric/span name drift and conventions.
    Metric,
    /// R6 — atomic-write enforcement for state files.
    AtomicWrite,
}

/// All rules, in id order.
pub const ALL_RULES: [Rule; 6] = [
    Rule::Panic,
    Rule::Unsafe,
    Rule::Lock,
    Rule::Knob,
    Rule::Metric,
    Rule::AtomicWrite,
];

impl Rule {
    /// Stable short id (`R1`..`R6`) used in findings and the baseline.
    pub fn id(self) -> &'static str {
        match self {
            Rule::Panic => "R1",
            Rule::Unsafe => "R2",
            Rule::Lock => "R3",
            Rule::Knob => "R4",
            Rule::Metric => "R5",
            Rule::AtomicWrite => "R6",
        }
    }

    /// Human key used by `--rules` and `// lint: allow(<key>)`.
    pub fn key(self) -> &'static str {
        match self {
            Rule::Panic => "panic",
            Rule::Unsafe => "unsafe",
            Rule::Lock => "lock",
            Rule::Knob => "knob",
            Rule::Metric => "metric",
            Rule::AtomicWrite => "atomic-write",
        }
    }

    /// Parse a `--rules` item (key or id, e.g. `panic` or `R1`).
    pub fn from_arg(s: &str) -> Result<Rule> {
        ALL_RULES
            .into_iter()
            .find(|r| r.key() == s || r.id() == s || r.id().to_lowercase() == s)
            .ok_or_else(|| Error::Config(format!("unknown lint rule `{s}`")))
    }
}

/// One lint finding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// The rule that fired.
    pub rule: Rule,
    /// Repo-relative file path the finding is in.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Human-readable description.
    pub message: String,
}

impl Finding {
    /// Construct a finding.
    pub fn new(rule: Rule, file: impl Into<String>, line: usize, message: String) -> Finding {
        Finding { rule, file: file.into(), line, message }
    }
}

/// One source file of the analyzed tree.
pub struct SourceFile {
    /// Repo-relative, `/`-separated path (e.g. `rust/src/gf/mod.rs`).
    pub path: String,
    /// Raw file contents.
    pub text: String,
}

/// Everything the analyzer looks at: the Rust sources plus the docs
/// corpus the drift rules (R4/R5) cross-check against.
pub struct Tree {
    /// All `rust/src/**/*.rs` files, path-sorted.
    pub sources: Vec<SourceFile>,
    /// `docs/ARCHITECTURE.md` (empty if absent — R4 will complain).
    pub architecture: String,
    /// `docs/OPERATIONS.md` (empty if absent).
    pub operations: String,
    /// Concatenation of all docs R5 accepts names from
    /// (ARCHITECTURE, OPERATIONS, OBSERVABILITY, STATIC_ANALYSIS, README).
    pub docs_corpus: String,
}

/// Recursively collect `.rs` files under `dir` into `out`.
fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| Error::Config(format!("cannot read {}: {e}", dir.display())))?;
    for entry in entries {
        let path = entry
            .map_err(|e| Error::Config(format!("cannot read {}: {e}", dir.display())))?
            .path();
        if path.is_dir() {
            walk_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Load the analyzable tree rooted at `root` (the repo root — the
/// directory containing `rust/` and `docs/`).
pub fn load_tree(root: &Path) -> Result<Tree> {
    let src = root.join("rust").join("src");
    if !src.is_dir() {
        return Err(Error::Config(format!(
            "{} does not look like the repo root (no rust/src)",
            root.display()
        )));
    }
    let mut paths = Vec::new();
    walk_rs(&src, &mut paths)?;
    paths.sort();
    let mut sources = Vec::with_capacity(paths.len());
    for path in paths {
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Config(format!("cannot read {}: {e}", path.display())))?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        sources.push(SourceFile { path: rel, text });
    }
    let read_doc = |name: &str| std::fs::read_to_string(root.join(name)).unwrap_or_default();
    let architecture = read_doc("docs/ARCHITECTURE.md");
    let operations = read_doc("docs/OPERATIONS.md");
    let mut docs_corpus = String::new();
    for name in [
        "docs/ARCHITECTURE.md",
        "docs/OPERATIONS.md",
        "docs/OBSERVABILITY.md",
        "docs/STATIC_ANALYSIS.md",
        "README.md",
    ] {
        docs_corpus.push_str(&read_doc(name));
        docs_corpus.push('\n');
    }
    Ok(Tree { sources, architecture, operations, docs_corpus })
}

/// Run the enabled rules over `tree`; findings come back sorted by
/// (file, line, rule).
pub fn analyze(tree: &Tree, enabled: &[Rule]) -> Vec<Finding> {
    let mut out = Vec::new();
    let doc_names = rules::DocNames::build(&tree.docs_corpus);
    for file in &tree.sources {
        let masked = lexer::mask(&file.text);
        let test_ranges = lexer::cfg_test_ranges(&masked);
        let allows = rules::allow_map(&masked);
        let newlines: Vec<usize> = masked
            .code
            .bytes()
            .enumerate()
            .filter_map(|(i, b)| (b == b'\n').then_some(i))
            .collect();
        let ctx = rules::FileCtx {
            path: &file.path,
            masked: &masked,
            test_ranges: &test_ranges,
            allows: &allows,
            newlines: &newlines,
        };
        if enabled.contains(&Rule::Panic) {
            rules::r1_panic(&ctx, &mut out);
        }
        if enabled.contains(&Rule::Unsafe) {
            rules::r2_unsafe(&ctx, &mut out);
        }
        if enabled.contains(&Rule::Lock) {
            rules::r3_lock(&ctx, &mut out);
        }
        if enabled.contains(&Rule::Metric) {
            rules::r5_metrics(&ctx, &doc_names, &mut out);
        }
        if enabled.contains(&Rule::AtomicWrite) {
            rules::r6_atomic(&ctx, &mut out);
        }
        if enabled.contains(&Rule::Knob) && file.path.ends_with("config/mod.rs") {
            let tests = test_ranges.clone();
            rules::r4_knobs(
                &file.path,
                &masked,
                &tests,
                &tree.architecture,
                &tree.operations,
                &mut out,
            );
        }
    }
    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule.id()).cmp(&(b.file.as_str(), b.line, b.rule.id()))
    });
    out
}

/// Options for one `drs lint` run.
#[derive(Debug, Clone, Default)]
pub struct LintOptions {
    /// Emit machine-readable JSON instead of the human report.
    pub json: bool,
    /// Rewrite `lint_baseline.json` (ratchet: refuses any growth).
    pub update_baseline: bool,
    /// Restrict to these rules (`None` = all six).
    pub rules: Option<Vec<Rule>>,
    /// Repo root override (`None` = auto-detect from the cwd).
    pub root: Option<String>,
}

/// Locate the repo root: the given override, else the first of `.`,
/// `..`, `../..` that contains `rust/src`.
fn find_root(over: &Option<String>) -> Result<PathBuf> {
    if let Some(r) = over {
        return Ok(PathBuf::from(r));
    }
    for cand in [".", "..", "../.."] {
        let p = PathBuf::from(cand);
        if p.join("rust").join("src").is_dir() {
            return Ok(p);
        }
    }
    Err(Error::Config(
        "cannot find the repo root (no rust/src here or above); pass --root DIR".to_string(),
    ))
}

/// Render findings + baseline comparison as a JSON document.
fn render_json(findings: &[Finding], current: &Baseline, regs: &[baseline::Regression]) -> String {
    let findings_json = Json::Arr(
        findings
            .iter()
            .map(|f| {
                Json::obj(vec![
                    ("rule", Json::str(f.rule.id())),
                    ("key", Json::str(f.rule.key())),
                    ("file", Json::str(f.file.as_str())),
                    ("line", Json::num(f.line as f64)),
                    ("message", Json::str(f.message.as_str())),
                ])
            })
            .collect(),
    );
    let counts_json = Json::Obj(
        current
            .counts
            .iter()
            .map(|(rule, files)| {
                let files_json = Json::Obj(
                    files
                        .iter()
                        .map(|(f, &n)| (f.clone(), Json::num(n as f64)))
                        .collect::<BTreeMap<_, _>>(),
                );
                (rule.clone(), files_json)
            })
            .collect::<BTreeMap<_, _>>(),
    );
    let regs_json = Json::Arr(
        regs.iter()
            .map(|r| {
                Json::obj(vec![
                    ("rule", Json::str(r.rule.as_str())),
                    ("file", Json::str(r.file.as_str())),
                    ("baseline", Json::num(r.baseline as f64)),
                    ("current", Json::num(r.current as f64)),
                ])
            })
            .collect(),
    );
    Json::obj(vec![
        ("findings", findings_json),
        ("counts", counts_json),
        ("regressions", regs_json),
        ("ok", Json::Bool(regs.is_empty())),
    ])
    .to_string()
}

/// Entry point for the `drs lint` CLI verb. Returns `Err` (non-zero
/// exit) when any (rule, file) count regresses past the baseline, or
/// when `--update-baseline` would grow it.
pub fn run(opts: &LintOptions) -> Result<()> {
    if opts.update_baseline && opts.rules.is_some() {
        return Err(Error::Config(
            "refusing --update-baseline with --rules: a partial run would drop the \
             other rules' baseline entries"
                .to_string(),
        ));
    }
    let root = find_root(&opts.root)?;
    let enabled: Vec<Rule> = match &opts.rules {
        Some(rs) => rs.clone(),
        None => ALL_RULES.to_vec(),
    };
    let tree = load_tree(&root)?;
    let findings = analyze(&tree, &enabled);
    let current = Baseline::from_findings(&findings);
    let base_path = root.join("lint_baseline.json");
    let base = Baseline::load(&base_path)?;
    let regs = base.regressions(&current);

    if opts.update_baseline {
        let next = base.ratchet(&current)?;
        next.save(&base_path)?;
        println!(
            "lint baseline updated: {} tolerated finding(s) across {} rule(s)",
            next.total(),
            next.counts.len()
        );
        return Ok(());
    }

    if opts.json {
        println!("{}", render_json(&findings, &current, &regs));
    } else {
        for f in &findings {
            println!("{} {}:{} {}", f.rule.id(), f.file, f.line, f.message);
        }
        let scanned = tree.sources.len();
        println!(
            "lint: {} finding(s) across {scanned} file(s); baseline tolerates {}; {} regression(s)",
            findings.len(),
            base.total(),
            regs.len()
        );
        for r in &regs {
            println!(
                "  REGRESSION {} {}: {} tolerated, {} found",
                r.rule, r.file, r.baseline, r.current
            );
        }
    }
    if regs.is_empty() {
        Ok(())
    } else {
        Err(Error::Config(format!(
            "lint found {} regression(s) past lint_baseline.json",
            regs.len()
        )))
    }
}
