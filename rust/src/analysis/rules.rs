//! The six rule passes of `drs lint`. See `docs/STATIC_ANALYSIS.md`
//! for the operator-facing catalogue; this module is the
//! implementation.
//!
//! Every pass works on [`lexer::Masked`] text — strings and comments
//! blanked — so a needle scan can never match inside either. Passes
//! R1/R2/R3/R6 are per-file and scope-aware (`#[cfg(test)]` regions
//! and `tests/`/`benches/` paths are exempt); R4/R5 are tree-level
//! drift checks between code and the committed docs.

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::{self, Masked};
use super::{lock_order, Finding, Rule};

/// Everything a per-file pass needs about one source file.
pub struct FileCtx<'a> {
    /// Repo-relative, `/`-separated path.
    pub path: &'a str,
    /// Masked source (strings/comments blanked).
    pub masked: &'a Masked,
    /// `#[cfg(test)]` line ranges.
    pub test_ranges: &'a [(usize, usize)],
    /// Parsed `// lint: allow(<rule>)` comments: rule key → lines.
    pub allows: &'a BTreeMap<String, BTreeSet<usize>>,
    /// Byte offset of each `\n` in the masked text (for line lookup).
    pub newlines: &'a [usize],
}

impl FileCtx<'_> {
    /// 1-based line containing byte `offset`.
    pub fn line_of(&self, offset: usize) -> usize {
        self.newlines.partition_point(|&n| n < offset) + 1
    }

    /// Whether `line` is inside test code.
    pub fn in_test(&self, line: usize) -> bool {
        lexer::in_ranges(self.test_ranges, line)
    }

    /// Whether findings of `rule` are allowed (suppressed) on `line`.
    pub fn allowed(&self, rule: Rule, line: usize) -> bool {
        self.allows.get(rule.key()).is_some_and(|s| s.contains(&line))
    }

    /// Whether the whole file is exempt from panic/unsafe hygiene
    /// (integration tests and benches may unwrap freely).
    pub fn test_path(&self) -> bool {
        let p = self.path;
        p.contains("/tests/") || p.contains("/benches/") || p.starts_with("tests/") || p.starts_with("benches/")
    }
}

/// Parse every `// lint: allow(<rule>) — <reason>` comment into a map
/// of rule key → suppressed lines. An allow covers the comment's own
/// line(s) and the first following code line, so it works both inline
/// and as a preceding annotation. Allows without a reason are ignored
/// (the grammar requires one).
pub fn allow_map(masked: &Masked) -> BTreeMap<String, BTreeSet<usize>> {
    let mut map: BTreeMap<String, BTreeSet<usize>> = BTreeMap::new();
    let lines = masked.code_lines();
    for c in &masked.comments {
        let Some(at) = c.text.find("lint: allow(") else { continue };
        let rest = &c.text[at + "lint: allow(".len()..];
        let Some(close) = rest.find(')') else { continue };
        let key = rest[..close].trim().to_string();
        let reason = rest[close + 1..]
            .trim_start_matches([' ', '\u{2014}', '-', ':', '\u{2013}'])
            .trim();
        if key.is_empty() || reason.is_empty() {
            continue;
        }
        let entry = map.entry(key).or_default();
        for l in c.line..=c.end_line {
            entry.insert(l);
        }
        // Extend to the next code line (at most a few lines ahead).
        for l in c.end_line..c.end_line + 10 {
            match lines.get(l) {
                Some(text) if text.trim().is_empty() => continue,
                Some(_) => {
                    entry.insert(l + 1);
                    break;
                }
                None => break,
            }
        }
    }
    map
}

/// Is `b[i]` the start of `needle` with a non-identifier byte before
/// it (so `dont_panic!` does not match `panic!`)?
fn word_start(b: &str, i: usize) -> bool {
    i == 0 || {
        let c = b.as_bytes()[i - 1];
        !(c.is_ascii_alphanumeric() || c == b'_')
    }
}

/// All byte offsets of `needle` in `hay`.
fn occurrences(hay: &str, needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(p) = hay[from..].find(needle) {
        out.push(from + p);
        from += p + 1;
    }
    out
}

/// The masked text immediately before `at`, with trailing whitespace
/// (including newlines — chains wrap) skipped.
fn before_nonspace(code: &str, at: usize) -> &str {
    let mut end = at;
    let b = code.as_bytes();
    while end > 0 && (b[end - 1] as char).is_whitespace() {
        end -= 1;
    }
    &code[..end]
}

/// Skip whitespace forward from `at`.
fn next_nonspace(code: &str, at: usize) -> usize {
    let b = code.as_bytes();
    let mut i = at;
    while i < b.len() && (b[i] as char).is_whitespace() {
        i += 1;
    }
    i
}

// ---------------------------------------------------------------- R1

/// R1 — panic-freedom: no `unwrap`/`expect`/`panic!`-family calls in
/// non-test library code. `.lock().unwrap()` sites are *not* counted
/// here — R3 flags them as poisoning-cascade sites, so each site is
/// reported exactly once under the rule that owns the fix.
pub fn r1_panic(ctx: &FileCtx<'_>, out: &mut Vec<Finding>) {
    if ctx.test_path() {
        return;
    }
    let code = &ctx.masked.code;
    const METHODS: [&str; 2] = [".unwrap()", ".expect("];
    const MACROS: [&str; 4] = ["panic!", "todo!", "unimplemented!", "unreachable!"];
    for needle in METHODS {
        for at in occurrences(code, needle) {
            if needle == ".unwrap()" && before_nonspace(code, at).ends_with(".lock()") {
                continue; // R3's finding, not R1's
            }
            let line = ctx.line_of(at);
            if ctx.in_test(line) || ctx.allowed(Rule::Panic, line) {
                continue;
            }
            out.push(Finding::new(
                Rule::Panic,
                ctx.path,
                line,
                format!("`{needle}` in non-test library code — return a typed drs::Error instead"),
            ));
        }
    }
    for needle in MACROS {
        for at in occurrences(code, needle) {
            if !word_start(code, at) {
                continue;
            }
            let line = ctx.line_of(at);
            if ctx.in_test(line) || ctx.allowed(Rule::Panic, line) {
                continue;
            }
            out.push(Finding::new(
                Rule::Panic,
                ctx.path,
                line,
                format!("`{needle}` in non-test library code — return a typed drs::Error instead"),
            ));
        }
    }
}

// ---------------------------------------------------------------- R2

/// R2 — unsafe hygiene: every `unsafe` block/impl is immediately
/// preceded by a `// SAFETY:` comment, and every `unsafe fn`
/// additionally documents a `# Safety` section.
pub fn r2_unsafe(ctx: &FileCtx<'_>, out: &mut Vec<Finding>) {
    if ctx.test_path() {
        return;
    }
    let code = &ctx.masked.code;
    let lines = ctx.masked.code_lines();
    // Lines carrying a SAFETY: comment / a `# Safety` doc heading.
    let mut safety_lines = BTreeSet::new();
    let mut safety_doc_lines = BTreeSet::new();
    for c in &ctx.masked.comments {
        if c.text.contains("SAFETY:") {
            for l in c.line..=c.end_line {
                safety_lines.insert(l);
            }
        }
        if c.text.contains("# Safety") {
            for l in c.line..=c.end_line {
                safety_doc_lines.insert(l);
            }
        }
    }
    // Walk upward from `line - 1` through comment/attribute/blank
    // lines; true if any walked line is in `wanted`.
    let covered = |line: usize, wanted: &BTreeSet<usize>| -> bool {
        if wanted.contains(&line) {
            return true;
        }
        let mut l = line.saturating_sub(1);
        while l >= 1 {
            if wanted.contains(&l) {
                return true;
            }
            let text = lines.get(l - 1).map(|s| s.trim()).unwrap_or("");
            let walkable = text.is_empty() || text.starts_with("#[") || text.starts_with(")]");
            if !walkable {
                return false;
            }
            l -= 1;
        }
        false
    };
    for at in occurrences(code, "unsafe") {
        if !word_start(code, at) {
            continue;
        }
        let after = code.as_bytes().get(at + 6).copied().unwrap_or(b' ');
        if after.is_ascii_alphanumeric() || after == b'_' {
            continue; // identifier like unsafe_op_in_unsafe_fn
        }
        let line = ctx.line_of(at);
        if ctx.in_test(line) || ctx.allowed(Rule::Unsafe, line) {
            continue;
        }
        let next = next_nonspace(code, at + 6);
        let is_fn = code[next..].starts_with("fn ") || code[next..].starts_with("fn(");
        if is_fn {
            // An `unsafe fn` declaration is a contract, not an
            // operation: its `# Safety` doc section is the
            // justification, so no `// SAFETY:` comment is demanded.
            if !covered(line, &safety_doc_lines) {
                out.push(Finding::new(
                    Rule::Unsafe,
                    ctx.path,
                    line,
                    "`unsafe fn` without a `# Safety` doc section".to_string(),
                ));
            }
        } else if !covered(line, &safety_lines) {
            out.push(Finding::new(
                Rule::Unsafe,
                ctx.path,
                line,
                "`unsafe` without an immediately preceding `// SAFETY:` comment".to_string(),
            ));
        }
    }
}

// ---------------------------------------------------------------- R3

/// One tracked lock acquisition during the R3 scan.
struct Held {
    class: &'static str,
    depth: usize,
    /// Temporary guards are released at the end of their statement.
    temp: bool,
}

/// R3 — lock discipline: `.lock()` / `util::lock(..)` sites are
/// classified via [`lock_order`]; lexically nested acquisitions must
/// follow the declared order, unknown mutexes must be registered, and
/// `.lock().unwrap()` is flagged as a poisoning-cascade site
/// (recoverable paths should use `util::lock`).
pub fn r3_lock(ctx: &FileCtx<'_>, out: &mut Vec<Finding>) {
    let code = &ctx.masked.code;
    let b = code.as_bytes();
    // Collect candidate sites first: (offset_of_token, kind).
    #[derive(Clone, Copy, PartialEq)]
    enum Kind {
        Method, // `.lock()`
        Helper, // `util::lock(`
    }
    let mut sites: Vec<(usize, Kind)> = Vec::new();
    for at in occurrences(code, ".lock()") {
        sites.push((at, Kind::Method));
    }
    for at in occurrences(code, "util::lock(") {
        sites.push((at, Kind::Helper));
    }
    sites.sort_by_key(|&(a, _)| a);

    let mut held: Vec<Held> = Vec::new();
    let mut depth = 0usize;
    let mut stmt_start = 0usize; // offset just after the last ; { }
    let mut site_iter = sites.into_iter().peekable();
    let mut i = 0usize;
    while i < b.len() {
        if let Some(&(at, kind)) = site_iter.peek() {
            if at == i {
                site_iter.next();
                let line = ctx.line_of(at);
                let skip = ctx.in_test(line);
                // Receiver text: method form walks back over the
                // chain; helper form reads the argument list.
                let (receiver, call_end) = match kind {
                    Kind::Method => (receiver_before(code, at).to_string(), at + ".lock()".len()),
                    Kind::Helper => {
                        let open = at + "util::lock(".len() - 1;
                        let close = matching_paren(b, open);
                        (code[open + 1..close.min(code.len())].to_string(), close + 1)
                    }
                };
                if !skip {
                    let class = lock_order::classify(&receiver, ctx.path);
                    // Poison cascade: `.lock().unwrap()`.
                    if kind == Kind::Method
                        && code[next_nonspace(code, call_end)..].starts_with(".unwrap()")
                        && !ctx.allowed(Rule::Lock, line)
                    {
                        out.push(Finding::new(
                            Rule::Lock,
                            ctx.path,
                            line,
                            format!(
                                "`.lock().unwrap()` on `{}` — a panicked holder poisons every later caller; use util::lock or annotate why poisoning is wanted",
                                receiver.trim()
                            ),
                        ));
                    }
                    match class {
                        None => {
                            if !ctx.allowed(Rule::Lock, line) {
                                out.push(Finding::new(
                                    Rule::Lock,
                                    ctx.path,
                                    line,
                                    format!(
                                        "lock on `{}` has no class in analysis::lock_order — register it so ordering is checked",
                                        receiver.trim()
                                    ),
                                ));
                            }
                        }
                        Some(class) => {
                            for h in &held {
                                if !lock_order::allows(h.class, class)
                                    && !ctx.allowed(Rule::Lock, line)
                                {
                                    out.push(Finding::new(
                                        Rule::Lock,
                                        ctx.path,
                                        line,
                                        format!(
                                            "`{class}` acquired while `{}` is held — not in the declared lock order (analysis::lock_order)",
                                            h.class
                                        ),
                                    ));
                                }
                            }
                            held.push(Held {
                                class,
                                depth,
                                temp: is_temporary(code, stmt_start, at, call_end),
                            });
                        }
                    }
                }
                i = call_end.max(i + 1);
                continue;
            }
        }
        match b[i] {
            b'{' => {
                depth += 1;
                stmt_start = i + 1;
            }
            b'}' => {
                depth = depth.saturating_sub(1);
                stmt_start = i + 1;
                // A block closing back down to a temporary's depth
                // ends the statement that created it (`for` loops over
                // a guard, `if cond { .. }` with a guard in `cond`).
                held.retain(|h| h.depth <= depth && !(h.temp && h.depth == depth));
            }
            b';' => {
                stmt_start = i + 1;
                held.retain(|h| !(h.temp && h.depth == depth));
            }
            _ => {}
        }
        i += 1;
    }
}

/// The receiver expression text left of a `.lock()` at `dot`: walks
/// back over identifier chars, `.`/`::`, balanced `[..]`/`(..)`
/// groups and line-wrapped chains.
fn receiver_before(code: &str, dot: usize) -> &str {
    let b = code.as_bytes();
    let mut i = dot;
    loop {
        if i == 0 {
            break;
        }
        // Whitespace may be bridged only when the construct to its
        // right is chain punctuation (`.`/`::`) — that covers wrapped
        // chains like `shard\n    .lock()` while stopping receivers
        // from swallowing the previous statement (`return\n x.lock()`).
        let right = if i == dot { b'.' } else { b[i] };
        let mut j = i;
        while j > 0 && (b[j - 1] as char).is_whitespace() {
            j -= 1;
        }
        if j != i && !(right == b'.' || right == b':') {
            break;
        }
        if j == 0 {
            i = 0;
            break;
        }
        let c = b[j - 1];
        if c.is_ascii_alphanumeric() || c == b'_' || c == b'.' || c == b':' {
            i = j - 1;
        } else if c == b']' || c == b')' {
            i = open_of(b, j - 1);
        } else {
            break;
        }
    }
    &code[i..dot]
}

/// Offset of the opener matching the closer at `close`.
fn open_of(b: &[u8], close: usize) -> usize {
    let (op, cl) = match b[close] {
        b')' => (b'(', b')'),
        _ => (b'[', b']'),
    };
    let mut depth = 0usize;
    let mut i = close;
    loop {
        if b[i] == cl {
            depth += 1;
        } else if b[i] == op {
            depth -= 1;
            if depth == 0 {
                return i;
            }
        }
        if i == 0 {
            return 0;
        }
        i -= 1;
    }
}

/// Offset of the `)` matching the `(` at `open` (or end of input).
fn matching_paren(b: &[u8], open: usize) -> usize {
    let mut depth = 0usize;
    let mut i = open;
    while i < b.len() {
        match b[i] {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return i;
                }
            }
            _ => {}
        }
        i += 1;
    }
    b.len().saturating_sub(1)
}

/// Guard-extent heuristic for R3. A guard is *block-scoped* (held to
/// the end of the enclosing block) when its statement starts with
/// `let`/`if let`/`while let`/`match` and the lock call's result is
/// bound directly (`;`-terminated, at most a chained `.unwrap()`), or
/// when it is a scrutinee (`if let`/`match` keep the temporary alive
/// for the whole arm body). Anything else is a statement-scoped
/// temporary.
fn is_temporary(code: &str, stmt_start: usize, _at: usize, call_end: usize) -> bool {
    let head = code[stmt_start..].trim_start();
    if head.starts_with("if let") || head.starts_with("while let") || head.starts_with("match ") {
        return false;
    }
    if head.starts_with("let ") {
        let mut tail = next_nonspace(code, call_end);
        if code[tail..].starts_with(".unwrap()") {
            tail = next_nonspace(code, tail + ".unwrap()".len());
        }
        let rest = code[tail..].trim_start_matches('?').trim_start();
        return !rest.starts_with(';');
    }
    true
}

// ---------------------------------------------------------------- R6

/// R6 — atomic-write enforcement: raw `fs::write`/`File::create`
/// calls outside `util` must carry an allow-comment explaining why
/// the write is not workspace state (SE object payloads, append-only
/// logs with their own crash protocol). Workspace state files go
/// through `util::atomic_write`.
pub fn r6_atomic(ctx: &FileCtx<'_>, out: &mut Vec<Finding>) {
    if ctx.test_path() || ctx.path.ends_with("util/mod.rs") {
        return;
    }
    let code = &ctx.masked.code;
    for needle in ["fs::write(", "File::create("] {
        for at in occurrences(code, needle) {
            let line = ctx.line_of(at);
            if ctx.in_test(line) || ctx.allowed(Rule::AtomicWrite, line) {
                continue;
            }
            out.push(Finding::new(
                Rule::AtomicWrite,
                ctx.path,
                line,
                format!(
                    "raw `{}..)` — workspace state must go through util::atomic_write; non-state writes need `// lint: allow(atomic-write) — why`",
                    needle
                ),
            ));
        }
    }
}

// ---------------------------------------------------------------- R4

/// Knobs that are structural rather than scalar and are exempt from
/// the env-binding/doc-row requirement (each with the reason).
const R4_STRUCTURAL: &[&str] = &[
    "ses",     // the SE inventory: a list, configured by `drs init`/file edits
    "network", // the simulator's latency profile object
];

/// Field-specific overrides: (field, env bindings, doc aliases).
/// A field passes the env check when *any* listed binding exists, and
/// the doc check when the field name *or* any alias appears.
const R4_ALIASES: &[(&str, &[&str], &[&str])] = &[
    ("params", &["DRS_K", "DRS_M"], &["--k", "--m"]),
    ("policy", &["DRS_PLACEMENT"], &["placement"]),
];

/// `DRS_*` variables that are real but deliberately not config knobs.
const R4_NON_CONFIG_ENVS: &[&str] = &["DRS_ARTIFACTS", "DRS_PROP_SEED"];

/// Does `doc` contain `name` delimited by non-identifier characters?
fn doc_has_token(doc: &str, name: &str) -> bool {
    let mut from = 0usize;
    while let Some(p) = doc[from..].find(name) {
        let at = from + p;
        let before_ok = at == 0 || {
            let c = doc.as_bytes()[at - 1];
            !(c.is_ascii_alphanumeric() || c == b'_')
        };
        let end = at + name.len();
        let after_ok = end >= doc.len() || {
            let c = doc.as_bytes()[end];
            !(c.is_ascii_alphanumeric() || c == b'_')
        };
        if before_ok && after_ok {
            return true;
        }
        from = at + 1;
    }
    false
}

/// R4 — knob drift. Cross-checks the `Config` struct in
/// `config/mod.rs` against its `DRS_*` env bindings and the two
/// operator docs, in both directions.
pub fn r4_knobs(
    config_path: &str,
    config: &Masked,
    config_tests: &[(usize, usize)],
    architecture: &str,
    operations: &str,
    out: &mut Vec<Finding>,
) {
    // -- collect the Config struct's fields (name, line) --
    let code = &config.code;
    let Some(start) = code.find("pub struct Config") else {
        out.push(Finding::new(
            Rule::Knob,
            config_path,
            1,
            "could not locate `pub struct Config` for the knob-drift check".to_string(),
        ));
        return;
    };
    let b = code.as_bytes();
    let open = match code[start..].find('{') {
        Some(p) => start + p,
        None => return,
    };
    let close = {
        let mut depth = 0usize;
        let mut i = open;
        loop {
            match b.get(i) {
                Some(b'{') => depth += 1,
                Some(b'}') => {
                    depth -= 1;
                    if depth == 0 {
                        break i;
                    }
                }
                None => break i,
                _ => {}
            }
            i += 1;
        }
    };
    let body_line0 = code[..open].matches('\n').count() + 1;
    let mut fields: Vec<(String, usize)> = Vec::new();
    for (k, raw) in code[open..close].lines().enumerate() {
        let t = raw.trim();
        if let Some(rest) = t.strip_prefix("pub ") {
            if let Some(colon) = rest.find(':') {
                let name = rest[..colon].trim();
                if !name.is_empty() && name.bytes().all(|c| c.is_ascii_lowercase() || c == b'_') {
                    fields.push((name.to_string(), body_line0 + k));
                }
            }
        }
    }
    // -- env literals actually bound in non-test config code --
    let env_lits: BTreeSet<&str> = config
        .strings
        .iter()
        .filter(|s| s.text.starts_with("DRS_") && !lexer::in_ranges(config_tests, s.line))
        .map(|s| s.text.as_str())
        .collect();
    // -- per-field checks --
    let mut expected_envs: BTreeSet<String> = BTreeSet::new();
    for (field, line) in &fields {
        if R4_STRUCTURAL.contains(&field.as_str()) {
            continue;
        }
        let alias = R4_ALIASES.iter().find(|(f, _, _)| f == field);
        let envs: Vec<String> = match alias {
            Some((_, envs, _)) => envs.iter().map(|e| e.to_string()).collect(),
            None => vec![format!("DRS_{}", field.to_uppercase())],
        };
        for e in &envs {
            expected_envs.insert(e.clone());
        }
        if !envs.iter().any(|e| env_lits.contains(e.as_str())) {
            out.push(Finding::new(
                Rule::Knob,
                config_path,
                *line,
                format!("config field `{field}` has no `{}` env binding in apply_env", envs[0]),
            ));
        }
        let doc_names: Vec<&str> = match alias {
            Some((_, _, aliases)) => {
                let mut v = vec![field.as_str()];
                v.extend(aliases.iter().copied());
                v
            }
            None => vec![field.as_str()],
        };
        for (doc, doc_file) in [(architecture, "docs/ARCHITECTURE.md"), (operations, "docs/OPERATIONS.md")] {
            if !doc_names.iter().any(|n| doc_has_token(doc, n)) {
                out.push(Finding::new(
                    Rule::Knob,
                    doc_file,
                    1,
                    format!("config knob `{field}` is not mentioned in {doc_file}"),
                ));
            }
        }
    }
    // -- reverse: every bound env literal must belong to a field --
    for lit in &env_lits {
        if !expected_envs.contains(*lit) && !R4_NON_CONFIG_ENVS.contains(lit) {
            out.push(Finding::new(
                Rule::Knob,
                config_path,
                1,
                format!("env binding `{lit}` does not correspond to any Config field"),
            ));
        }
    }
    // -- reverse: every DRS_* token in the docs must be a real knob --
    for (doc, doc_file) in [(architecture, "docs/ARCHITECTURE.md"), (operations, "docs/OPERATIONS.md")] {
        for tok in drs_tokens(doc) {
            // `DRS` alone is the `DRS_*` family wildcard, not a knob.
            if tok == "DRS" {
                continue;
            }
            if !expected_envs.contains(&tok) && !R4_NON_CONFIG_ENVS.contains(&tok.as_str()) {
                out.push(Finding::new(
                    Rule::Knob,
                    doc_file,
                    1,
                    format!("doc mentions `{tok}` which is not a bound config env"),
                ));
            }
        }
    }
}

/// Every maximal `DRS_[A-Z0-9_]*` token in `doc`.
fn drs_tokens(doc: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    let b = doc.as_bytes();
    let mut i = 0usize;
    while let Some(p) = doc[i..].find("DRS_") {
        let at = i + p;
        let before_ok = at == 0 || !(b[at - 1].is_ascii_alphanumeric() || b[at - 1] == b'_');
        let mut end = at + 4;
        while end < b.len() && (b[end].is_ascii_uppercase() || b[end].is_ascii_digit() || b[end] == b'_') {
            end += 1;
        }
        if before_ok {
            out.insert(doc[at..end].trim_end_matches('_').to_string());
        }
        i = end;
    }
    out
}

// ---------------------------------------------------------------- R5

/// Files exempt from R5: the metric/trace plumbing itself (generic
/// registries, fixtures) — their literals are API examples, not
/// emitted series.
fn r5_exempt(path: &str) -> bool {
    path.ends_with("metrics/mod.rs") || path.ends_with("obs/mod.rs")
}

/// Is `name` a well-formed dotted metric name (`area.noun.verb`
/// style: ≥ 2 lowercase segments separated by dots)?
pub fn metric_name_ok(name: &str) -> bool {
    let segs: Vec<&str> = name.split('.').collect();
    segs.len() >= 2
        && segs
            .iter()
            .all(|s| !s.is_empty() && s.bytes().all(|b| b.is_ascii_lowercase() || b.is_ascii_digit() || b == b'_'))
}

/// Is `name` a well-formed span/event name (lowercase, dash-joined)?
pub fn span_name_ok(name: &str) -> bool {
    let segs: Vec<&str> = name.split('-').collect();
    !segs.is_empty()
        && segs
            .iter()
            .all(|s| !s.is_empty() && s.bytes().all(|b| b.is_ascii_lowercase() || b.is_ascii_digit() || b == b'_'))
}

/// Build the set of documented names from the docs corpus: maximal
/// runs of `[a-z0-9_.{},-]` with `{a,b}` groups expanded, plus
/// wildcard prefixes for `foo.*` / trailing-dot forms.
pub struct DocNames {
    exact: BTreeSet<String>,
    prefixes: Vec<String>,
}

impl DocNames {
    /// Extract from the concatenated docs text.
    pub fn build(docs: &str) -> DocNames {
        let mut exact = BTreeSet::new();
        let mut prefixes = Vec::new();
        let is_tok = |c: char| {
            c.is_ascii_lowercase() || c.is_ascii_digit() || "._{},-*".contains(c)
        };
        for raw in docs.split(|c: char| !is_tok(c)) {
            if raw.is_empty() {
                continue;
            }
            for tok in expand_braces(raw) {
                let tok = tok.trim_matches(|c| c == ',' || c == '.').to_string();
                if tok.is_empty() {
                    continue;
                }
                if let Some(pre) = tok.strip_suffix(".*") {
                    prefixes.push(format!("{pre}."));
                } else if let Some(pre) = tok.strip_suffix('*') {
                    prefixes.push(pre.to_string());
                } else {
                    exact.insert(tok);
                }
            }
        }
        DocNames { exact, prefixes }
    }

    /// Whether `name` is documented (exact or by wildcard prefix).
    pub fn contains(&self, name: &str) -> bool {
        self.exact.contains(name) || self.prefixes.iter().any(|p| name.starts_with(p.as_str()))
    }
}

/// Expand one level of `{a,b,c}` alternation in `tok` (`x.{y,z}` →
/// `x.y`, `x.z`). Tokens without braces pass through; unbalanced
/// braces yield the token with braces stripped.
fn expand_braces(tok: &str) -> Vec<String> {
    let (Some(open), Some(close)) = (tok.find('{'), tok.rfind('}')) else {
        return vec![tok.to_string()];
    };
    if close < open {
        return vec![tok.replace(['{', '}'], "")];
    }
    let head = &tok[..open];
    let tail = &tok[close + 1..];
    tok[open + 1..close]
        .split(',')
        .flat_map(|mid| expand_braces(&format!("{head}{mid}{tail}")))
        .collect()
}

/// R5 — metric/trace-name drift: every statically named metric and
/// span emitted by library code must follow the naming convention and
/// appear in the docs corpus.
pub fn r5_metrics(ctx: &FileCtx<'_>, docs: &DocNames, out: &mut Vec<Finding>) {
    if ctx.test_path() || r5_exempt(ctx.path) {
        return;
    }
    let code = &ctx.masked.code;
    // Metric writers: name is the literal at the first argument.
    for needle in [".inc(", ".add(", ".gauge(", ".time(", ".timed("] {
        for at in occurrences(code, needle) {
            let arg = at + needle.len();
            let Some(lit) = ctx.masked.strings.iter().find(|s| s.offset == arg) else {
                continue; // dynamic name (format!) — out of scope
            };
            let line = ctx.line_of(at);
            if ctx.in_test(line) || ctx.allowed(Rule::Metric, line) {
                continue;
            }
            if !metric_name_ok(&lit.text) {
                out.push(Finding::new(
                    Rule::Metric,
                    ctx.path,
                    line,
                    format!("metric name `{}` does not follow the dotted `area.noun.verb` convention", lit.text),
                ));
            } else if !docs.contains(&lit.text) {
                out.push(Finding::new(
                    Rule::Metric,
                    ctx.path,
                    line,
                    format!("metric name `{}` is not documented in docs/*.md", lit.text),
                ));
            }
        }
    }
    // Span/event emitters: name is the first literal in the arg list
    // (the preceding args are plain expressions, never literals).
    for needle in [".span(", ".span_with(", ".event("] {
        for at in occurrences(code, needle) {
            let arg = at + needle.len();
            let Some(lit) = ctx
                .masked
                .strings
                .iter()
                .find(|s| s.offset > arg && s.offset < arg + 120)
            else {
                continue;
            };
            // Only simple arg expressions between call and literal —
            // otherwise the literal belongs to something else.
            let between = &code[arg..lit.offset];
            if between.contains('(') || between.contains('{') || between.contains(';') {
                continue;
            }
            let line = ctx.line_of(at);
            if ctx.in_test(line) || ctx.allowed(Rule::Metric, line) {
                continue;
            }
            if !span_name_ok(&lit.text) {
                out.push(Finding::new(
                    Rule::Metric,
                    ctx.path,
                    line,
                    format!("span name `{}` does not follow the lowercase-dash convention", lit.text),
                ));
            } else if !docs.contains(&lit.text) {
                out.push(Finding::new(
                    Rule::Metric,
                    ctx.path,
                    line,
                    format!("span name `{}` is not documented in docs/*.md", lit.text),
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metric_and_span_conventions() {
        assert!(metric_name_ok("cache.hits"));
        assert!(metric_name_ok("transfer.stream.blocks"));
        assert!(!metric_name_ok("cachehits"));
        assert!(!metric_name_ok("Cache.Hits"));
        assert!(!metric_name_ok("cache..hits"));
        assert!(span_name_ok("put"));
        assert!(span_name_ok("chunk-write"));
        assert!(!span_name_ok("Put"));
        assert!(!span_name_ok("chunk_write-"));
    }

    #[test]
    fn doc_names_expand_braces_and_wildcards() {
        let d = DocNames::build("counts `cache.{hits,misses}` and `maintenance.scrub.*` plus `daemon-tick`.");
        assert!(d.contains("cache.hits"));
        assert!(d.contains("cache.misses"));
        assert!(d.contains("maintenance.scrub.files"));
        assert!(d.contains("daemon-tick"));
        assert!(!d.contains("cache.evictions"));
    }

    #[test]
    fn brace_expansion_nested_tail() {
        assert_eq!(expand_braces("a.{b,c}"), vec!["a.b".to_string(), "a.c".to_string()]);
        assert_eq!(expand_braces("plain"), vec!["plain".to_string()]);
    }
}
