//! A minimal Rust-source lexer for the lint passes.
//!
//! The rule passes in [`super::rules`] are line- and token-oriented, so
//! the only lexing they need is *masking*: a copy of the source in
//! which every string literal, char literal and comment has its
//! contents blanked out (newlines preserved), so a substring scan over
//! the masked text can never match inside a string or a comment. The
//! lexer additionally returns the string literals and comments it
//! removed, with their positions, because two rules need them: the
//! knob/metric drift checks read literal values, and the hygiene rules
//! read comment text (`// SAFETY:`, `// lint: allow(...)`).
//!
//! Handled syntax: `//` and `///`//`//!` line comments, nested `/* */`
//! block comments (including doc forms), `"..."` and `b"..."` strings
//! with escapes, raw strings `r"..."`, `r#"..."#` (any hash count, and
//! the `br` forms), char/byte-char literals `'x'`/`b'\n'`, and the
//! lifetime-vs-char-literal ambiguity (`'a>` is a lifetime, `'a'` is a
//! char).

/// One string literal found in the source (raw contents, no quotes,
/// escapes left as written).
#[derive(Debug, Clone)]
pub struct StrLit {
    /// Byte offset of the opening quote in the original source.
    pub offset: usize,
    /// 1-based line of the opening quote.
    pub line: usize,
    /// The literal's contents (between the delimiters), unprocessed.
    pub text: String,
}

/// One comment found in the source (text includes the `//`/`/*`
/// markers).
#[derive(Debug, Clone)]
pub struct Comment {
    /// 1-based line the comment starts on.
    pub line: usize,
    /// 1-based line the comment ends on (same as `line` for `//`).
    pub end_line: usize,
    /// The raw comment text, markers included.
    pub text: String,
}

/// The result of masking one source file. See the module docs.
#[derive(Debug, Default)]
pub struct Masked {
    /// The source with string/char contents and comments blanked.
    /// Byte-for-byte the same length as the input; newlines kept.
    pub code: String,
    /// Every string literal, in source order.
    pub strings: Vec<StrLit>,
    /// Every comment, in source order.
    pub comments: Vec<Comment>,
}

impl Masked {
    /// The masked source split into lines (no terminators). Line `i`
    /// of the vector is source line `i + 1`.
    pub fn code_lines(&self) -> Vec<&str> {
        self.code.lines().collect()
    }
}

/// Is `b` an identifier byte (`[A-Za-z0-9_]` — multibyte identifier
/// chars are treated as opaque and never start lexer constructs)?
fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Mask `src` (see module docs). The masked output replaces every
/// blanked byte with a space, so byte offsets and line numbers in the
/// masked text equal those in the original.
pub fn mask(src: &str) -> Masked {
    let b = src.as_bytes();
    let mut out = b.to_vec();
    let mut strings = Vec::new();
    let mut comments = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;

    // Blank out[from..to], preserving newlines.
    let blank = |out: &mut [u8], from: usize, to: usize| {
        for x in out.iter_mut().take(to).skip(from) {
            if *x != b'\n' {
                *x = b' ';
            }
        }
    };

    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        // Line comment (covers `///` and `//!` doc comments).
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
            let start = i;
            while i < b.len() && b[i] != b'\n' {
                i += 1;
            }
            comments.push(Comment {
                line,
                end_line: line,
                text: String::from_utf8_lossy(&b[start..i]).into_owned(),
            });
            blank(&mut out, start, i);
            continue;
        }
        // Block comment, possibly nested (covers `/** */`, `/*! */`).
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
            let (start, start_line) = (i, line);
            let mut depth = 1usize;
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == b'\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            comments.push(Comment {
                line: start_line,
                end_line: line,
                text: String::from_utf8_lossy(&b[start..i]).into_owned(),
            });
            blank(&mut out, start, i);
            continue;
        }
        // Raw string: r"..." / r#"..."# / br#"..."# — only when the
        // `r`/`b` is not the tail of an identifier (`for"x"` is not).
        if (c == b'r' || c == b'b') && (i == 0 || !is_ident(b[i - 1])) {
            let mut j = i;
            if b[j] == b'b' {
                j += 1;
            }
            if j < b.len() && b[j] == b'r' {
                j += 1;
                let mut hashes = 0usize;
                while j < b.len() && b[j] == b'#' {
                    hashes += 1;
                    j += 1;
                }
                if j < b.len() && b[j] == b'"' {
                    // Committed: scan to `"` followed by `hashes` #s.
                    let content_start = j + 1;
                    let open_line = line;
                    let mut k = content_start;
                    loop {
                        if k >= b.len() {
                            break; // unterminated: mask to EOF
                        }
                        if b[k] == b'\n' {
                            line += 1;
                            k += 1;
                            continue;
                        }
                        if b[k] == b'"' && b[k + 1..].len() >= hashes
                            && b[k + 1..k + 1 + hashes].iter().all(|&h| h == b'#')
                        {
                            break;
                        }
                        k += 1;
                    }
                    strings.push(StrLit {
                        offset: j,
                        line: open_line,
                        text: String::from_utf8_lossy(&b[content_start..k.min(b.len())])
                            .into_owned(),
                    });
                    blank(&mut out, content_start, k.min(b.len()));
                    i = (k + 1 + hashes).min(b.len());
                    continue;
                }
            }
            // Not a raw string; `b"..."`/`b'...'` fall through to the
            // plain string/char arms below on the quote itself.
        }
        // Plain string literal (the `b` of `b"..."` was ordinary code).
        if c == b'"' {
            let content_start = i + 1;
            let open_line = line;
            let mut k = content_start;
            while k < b.len() {
                if b[k] == b'\\' {
                    k += 2;
                    continue;
                }
                if b[k] == b'\n' {
                    line += 1;
                    k += 1;
                    continue;
                }
                if b[k] == b'"' {
                    break;
                }
                k += 1;
            }
            strings.push(StrLit {
                offset: i,
                line: open_line,
                text: String::from_utf8_lossy(&b[content_start..k.min(b.len())]).into_owned(),
            });
            blank(&mut out, content_start, k.min(b.len()));
            i = (k + 1).min(b.len());
            continue;
        }
        // Char literal vs lifetime.
        if c == b'\'' {
            if i + 1 < b.len() && b[i + 1] == b'\\' {
                // Escaped char literal: consume exactly one escape
                // (`\n`, `\\`, `\'`, `\xNN`, `\u{..}`), landing `k` on
                // the closing quote.
                let mut k = i + 2;
                if k < b.len() {
                    match b[k] {
                        b'x' => k = (k + 3).min(b.len()),
                        b'u' => {
                            while k < b.len() && b[k] != b'}' && b[k] != b'\n' {
                                k += 1;
                            }
                            k = (k + 1).min(b.len());
                        }
                        _ => k += 1,
                    }
                }
                blank(&mut out, i + 1, k.min(b.len()));
                i = (k + 1).min(b.len());
                continue;
            }
            // One char (possibly multibyte) then a closing quote means
            // a char literal; anything else is a lifetime tick.
            let rest = &src[i + 1..];
            if let Some(ch) = rest.chars().next() {
                let after = i + 1 + ch.len_utf8();
                if after < b.len() && b[after] == b'\'' {
                    blank(&mut out, i + 1, after);
                    i = after + 1;
                    continue;
                }
            }
            i += 1;
            continue;
        }
        i += 1;
    }

    Masked {
        code: String::from_utf8_lossy(&out).into_owned(),
        strings,
        comments,
    }
}

/// 1-based line ranges (inclusive) of `#[cfg(test)]`-guarded items in
/// masked source, so rule passes can skip test code. The scan finds
/// each `#[cfg(test)]` attribute and claims either the next
/// brace-delimited item (a `mod tests { .. }`, a `fn`, an `impl`) or,
/// when a `;` arrives first, just that statement.
pub fn cfg_test_ranges(masked: &Masked) -> Vec<(usize, usize)> {
    let code = masked.code.as_bytes();
    let mut ranges = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    // Normalized needle match: `#[cfg(test)]` allowing interior spaces.
    let matches_attr = |code: &[u8], at: usize| -> Option<usize> {
        let needle = b"#[cfg(test)]";
        let mut n = 0usize;
        let mut j = at;
        while n < needle.len() {
            if j >= code.len() {
                return None;
            }
            if code[j] == b' ' && needle[n] != b' ' && n > 0 {
                j += 1; // skip incidental spacing
                continue;
            }
            if code[j] != needle[n] {
                return None;
            }
            j += 1;
            n += 1;
        }
        Some(j)
    };
    while i < code.len() {
        if code[i] == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if code[i] == b'#' {
            if let Some(end) = matches_attr(code, i) {
                let start_line = line;
                // Scan forward: a `;` before any `{` claims one
                // statement; otherwise claim the brace-balanced block.
                let mut j = end;
                let mut depth = 0usize;
                let mut entered = false;
                while j < code.len() {
                    match code[j] {
                        b'\n' => line += 1,
                        b';' if !entered => break,
                        b'{' => {
                            depth += 1;
                            entered = true;
                        }
                        b'}' => {
                            depth = depth.saturating_sub(1);
                            if entered && depth == 0 {
                                break;
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
                ranges.push((start_line, line));
                i = j.saturating_add(1);
                continue;
            }
        }
        i += 1;
    }
    ranges
}

/// Whether 1-based `line` falls in any of `ranges` (inclusive).
pub fn in_ranges(ranges: &[(usize, usize)], line: usize) -> bool {
    ranges.iter().any(|&(a, b)| line >= a && line <= b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_strings_comments_chars() {
        let src = "let a = \"un// wrap()\"; // .unwrap() here\nlet c = 'x';";
        let m = mask(src);
        assert!(!m.code.contains("unwrap"));
        assert!(m.code.contains("let a"));
        assert_eq!(m.strings.len(), 1);
        assert_eq!(m.strings[0].text, "un// wrap()");
        assert_eq!(m.comments.len(), 1);
        assert!(m.comments[0].text.contains(".unwrap()"));
        assert_eq!(m.code.len(), src.len());
    }

    #[test]
    fn raw_strings_any_hash_count() {
        let src = r####"let s = r#"panic!("no")"#; let t = r"x.unwrap()"; let u = br##"y"##;"####;
        let m = mask(src);
        assert!(!m.code.contains("panic!"));
        assert!(!m.code.contains("unwrap"));
        assert_eq!(m.strings.len(), 3);
        assert_eq!(m.strings[0].text, "panic!(\"no\")");
    }

    #[test]
    fn lifetimes_survive_char_literals_masked() {
        let src = "fn f<'a>(x: &'a str) -> char { let c = 'y'; let n = '\\n'; c }";
        let m = mask(src);
        assert!(m.code.contains("<'a>"));
        assert!(m.code.contains("&'a str"));
        assert!(!m.code.contains("'y'"));
        assert!(!m.code.contains("\\n"));
    }

    #[test]
    fn escaped_backslash_char_does_not_eat_the_line() {
        let src = "let b = '\\\\'; keep.this();";
        let m = mask(src);
        assert!(m.code.contains("keep.this()"), "{}", m.code);
        let src2 = "let u = '\\u{1F600}'; keep.this();";
        let m2 = mask(src2);
        assert!(m2.code.contains("keep.this()"), "{}", m2.code);
    }

    #[test]
    fn nested_block_comments() {
        let src = "a /* outer /* inner */ still */ b";
        let m = mask(src);
        assert!(m.code.contains('a'));
        assert!(m.code.contains('b'));
        assert!(!m.code.contains("inner"));
        assert!(!m.code.contains("still"));
    }

    #[test]
    fn cfg_test_region_claims_block() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n  fn t() { x.unwrap(); }\n}\nfn live2() {}\n";
        let m = mask(src);
        let r = cfg_test_ranges(&m);
        assert_eq!(r.len(), 1);
        assert!(in_ranges(&r, 4));
        assert!(!in_ranges(&r, 1));
        assert!(!in_ranges(&r, 6));
    }

    #[test]
    fn cfg_test_statement_form() {
        let src = "#[cfg(test)]\nuse foo::bar;\nfn live() { let y = x.other(); }\n";
        let m = mask(src);
        let r = cfg_test_ranges(&m);
        assert_eq!(r.len(), 1);
        assert!(in_ranges(&r, 2));
        assert!(!in_ranges(&r, 3));
    }
}
