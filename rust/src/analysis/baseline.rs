//! The ratcheting lint baseline.
//!
//! `lint_baseline.json` at the repo root records, per (rule, file),
//! how many findings are *tolerated* — the debt inherited when a rule
//! was introduced. A lint run fails only on **regressions**: a
//! (rule, file) cell whose current count exceeds the baseline. The
//! baseline may only shrink: `drs lint --update-baseline` rewrites it
//! from the current findings but refuses to grow any cell, so debt is
//! paid down monotonically and can never silently return.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::Json;
use crate::{Error, Result};

use super::Finding;

/// Baseline file format version.
const VERSION: u64 = 1;

/// Tolerated finding counts: rule id → file → count.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Baseline {
    /// rule id (e.g. `"R1"`) → repo-relative file → tolerated count.
    pub counts: BTreeMap<String, BTreeMap<String, u64>>,
}

/// One (rule, file) cell whose current count exceeds the baseline.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Regression {
    /// Rule id, e.g. `"R1"`.
    pub rule: String,
    /// Repo-relative file path.
    pub file: String,
    /// Tolerated count from the baseline.
    pub baseline: u64,
    /// Count observed in this run.
    pub current: u64,
}

impl Baseline {
    /// Aggregate findings into per-(rule, file) counts.
    pub fn from_findings(findings: &[Finding]) -> Baseline {
        let mut counts: BTreeMap<String, BTreeMap<String, u64>> = BTreeMap::new();
        for f in findings {
            *counts
                .entry(f.rule.id().to_string())
                .or_default()
                .entry(f.file.clone())
                .or_default() += 1;
        }
        Baseline { counts }
    }

    /// Load from `path`. A missing file is an empty baseline (every
    /// finding is then a regression — the strictest reading).
    pub fn load(path: &Path) -> Result<Baseline> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(Baseline::default());
            }
            Err(e) => return Err(e.into()),
        };
        let json = Json::parse(&text)
            .map_err(|e| Error::Config(format!("{}: {e}", path.display())))?;
        let version = json.get("version").and_then(Json::as_u64).unwrap_or(0);
        if version != VERSION {
            return Err(Error::Config(format!(
                "{}: unsupported baseline version {version} (want {VERSION})",
                path.display()
            )));
        }
        let mut counts: BTreeMap<String, BTreeMap<String, u64>> = BTreeMap::new();
        let rules = json
            .get("counts")
            .and_then(Json::as_obj)
            .ok_or_else(|| Error::Config(format!("{}: missing counts object", path.display())))?;
        for (rule, files) in rules {
            let files = files.as_obj().ok_or_else(|| {
                Error::Config(format!("{}: counts.{rule} is not an object", path.display()))
            })?;
            let cell = counts.entry(rule.clone()).or_default();
            for (file, n) in files {
                let n = n.as_u64().ok_or_else(|| {
                    Error::Config(format!("{}: counts.{rule}.{file} is not a count", path.display()))
                })?;
                cell.insert(file.clone(), n);
            }
        }
        Ok(Baseline { counts })
    }

    /// Serialize to the committed JSON form (pretty, stable order).
    pub fn to_json_string(&self) -> String {
        let mut out = String::from("{\n  \"version\": 1,\n  \"counts\": {");
        let mut first_rule = true;
        for (rule, files) in &self.counts {
            if !first_rule {
                out.push(',');
            }
            first_rule = false;
            out.push_str(&format!("\n    {}: {{", Json::str(rule.as_str())));
            let mut first_file = true;
            for (file, n) in files {
                if !first_file {
                    out.push(',');
                }
                first_file = false;
                out.push_str(&format!("\n      {}: {n}", Json::str(file.as_str())));
            }
            out.push_str("\n    }");
        }
        out.push_str("\n  }\n}\n");
        out
    }

    /// Atomically write to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        crate::util::atomic_write(path, self.to_json_string().as_bytes())
    }

    /// Every (rule, file) cell where `current` exceeds this baseline.
    pub fn regressions(&self, current: &Baseline) -> Vec<Regression> {
        let mut out = Vec::new();
        for (rule, files) in &current.counts {
            for (file, &n) in files {
                let tolerated = self
                    .counts
                    .get(rule)
                    .and_then(|m| m.get(file))
                    .copied()
                    .unwrap_or(0);
                if n > tolerated {
                    out.push(Regression {
                        rule: rule.clone(),
                        file: file.clone(),
                        baseline: tolerated,
                        current: n,
                    });
                }
            }
        }
        out
    }

    /// The ratchet: produce the updated baseline from `current`, or
    /// an error if any cell would grow. Cells that shrank or vanished
    /// are dropped to the smaller value — the baseline only ever
    /// tightens.
    pub fn ratchet(&self, current: &Baseline) -> Result<Baseline> {
        let regressions = self.regressions(current);
        if let Some(r) = regressions.first() {
            return Err(Error::Config(format!(
                "refusing to grow baseline: {} in {} went {} -> {} ({} regressed cell(s) total); fix the new findings or add an allow-comment with a reason",
                r.rule,
                r.file,
                r.baseline,
                r.current,
                regressions.len()
            )));
        }
        Ok(current.clone())
    }

    /// Total tolerated findings across all cells.
    pub fn total(&self) -> u64 {
        self.counts.values().flat_map(|m| m.values()).sum()
    }

    /// Total tolerated findings for one rule id.
    pub fn total_for(&self, rule: &str) -> u64 {
        self.counts.get(rule).map(|m| m.values().sum()).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::Rule;

    fn b(cells: &[(&str, &str, u64)]) -> Baseline {
        let mut counts: BTreeMap<String, BTreeMap<String, u64>> = BTreeMap::new();
        for &(rule, file, n) in cells {
            counts.entry(rule.into()).or_default().insert(file.into(), n);
        }
        Baseline { counts }
    }

    #[test]
    fn roundtrips_through_json() {
        let base = b(&[("R1", "rust/src/a.rs", 3), ("R3", "rust/src/b.rs", 1)]);
        let text = base.to_json_string();
        let dir = std::env::temp_dir().join(format!("drs-lintbase-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("base.json");
        std::fs::write(&path, &text).unwrap();
        assert_eq!(Baseline::load(&path).unwrap(), base);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_file_is_empty() {
        let path = std::env::temp_dir().join("drs-definitely-absent-baseline.json");
        assert_eq!(Baseline::load(&path).unwrap(), Baseline::default());
    }

    #[test]
    fn regressions_flag_growth_only() {
        let base = b(&[("R1", "a.rs", 2)]);
        assert!(base.regressions(&b(&[("R1", "a.rs", 2)])).is_empty());
        assert!(base.regressions(&b(&[("R1", "a.rs", 1)])).is_empty());
        let regs = base.regressions(&b(&[("R1", "a.rs", 3), ("R6", "c.rs", 1)]));
        assert_eq!(regs.len(), 2);
        assert_eq!(regs[0].rule, "R1");
        assert_eq!(regs[1].rule, "R6");
    }

    #[test]
    fn ratchet_refuses_growth_and_accepts_shrink() {
        let base = b(&[("R1", "a.rs", 2), ("R1", "b.rs", 1)]);
        let shrunk = base.ratchet(&b(&[("R1", "a.rs", 1)])).unwrap();
        assert_eq!(shrunk.total(), 1);
        assert!(base.ratchet(&b(&[("R1", "a.rs", 3)])).is_err());
    }

    #[test]
    fn from_findings_counts_cells() {
        let findings = vec![
            Finding::new(Rule::Panic, "a.rs", 1, "x".into()),
            Finding::new(Rule::Panic, "a.rs", 2, "y".into()),
            Finding::new(Rule::Lock, "b.rs", 3, "z".into()),
        ];
        let cur = Baseline::from_findings(&findings);
        assert_eq!(cur.counts["R1"]["a.rs"], 2);
        assert_eq!(cur.counts["R3"]["b.rs"], 1);
        assert_eq!(cur.total_for("R1"), 2);
    }
}
