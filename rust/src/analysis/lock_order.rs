//! The declared lock-order registry for rule R3 (lock discipline).
//!
//! The analyzer classifies every `.lock()` / `util::lock(..)` call
//! site into a *lock class* by matching the receiver text against the
//! patterns below, then checks lexically nested acquisitions against
//! the declared partial order: a nested pair `(outer, inner)` is legal
//! only when [`allows`] returns true for it. Same-class nesting is
//! always a violation (self-deadlock risk), and nesting a pair that no
//! declaration covers is a violation too — new nestings must be
//! declared here, which is the point: the registry is the reviewed,
//! versioned statement of which lock orders this crate permits.
//!
//! The declared order mirrors the invariants stated in the module docs
//! of the lock holders themselves, e.g. `catalog::store`: "Lock order
//! is always shard → journal, never the reverse."

/// A lock class: a name plus the receiver-substring patterns that
/// identify its acquisition sites. The first class whose pattern
/// matches claims the site; `file_hint`, when set, restricts the class
/// to paths containing that substring (lets two subsystems reuse a
/// receiver word without colliding).
pub struct LockClass {
    /// Stable class name used in findings and order declarations.
    pub name: &'static str,
    /// Substrings matched against the receiver expression text.
    pub patterns: &'static [&'static str],
    /// Optional path-substring filter.
    pub file_hint: Option<&'static str>,
}

/// Every known lock class. Order matters: first match wins, so more
/// specific classes come first.
pub const CLASSES: &[LockClass] = &[
    LockClass {
        name: "catalog-journal",
        patterns: &["journal"],
        file_hint: None,
    },
    LockClass {
        name: "shard",
        // ShardedDfc shards, cache shards, tracer ring shards.
        patterns: &["shard"],
        file_hint: None,
    },
    LockClass {
        name: "cache-lfn-index",
        patterns: &["lfns"],
        file_hint: None,
    },
    LockClass {
        name: "metrics-map",
        patterns: &["counters", "gauges", "timers"],
        file_hint: None,
    },
    LockClass {
        name: "trace-sink",
        patterns: &["sink"],
        file_hint: None,
    },
    LockClass {
        name: "daemon-status",
        patterns: &["live_status", "live", "bound"],
        file_hint: None,
    },
    LockClass {
        name: "stream-state",
        patterns: &["state"],
        file_hint: None,
    },
    LockClass {
        name: "stream-permits",
        patterns: &["permits"],
        file_hint: None,
    },
    LockClass {
        name: "remote-pool",
        // RemoteSe's idle-connection pool; never nested with anything.
        patterns: &["idle_conns"],
        file_hint: None,
    },
    LockClass {
        name: "proxy-mode",
        // testkit::FaultProxy's active-fault cell; copied out, never
        // held across I/O, never nested.
        patterns: &["mode"],
        file_hint: Some("testkit"),
    },
    LockClass {
        name: "pool-queue",
        patterns: &["queue"],
        file_hint: None,
    },
    LockClass {
        name: "pool-results",
        patterns: &["successes", "failures"],
        file_hint: None,
    },
    LockClass {
        name: "se-store",
        patterns: &["store"],
        file_hint: None,
    },
    LockClass {
        name: "pjrt-registry",
        patterns: &["inner"],
        file_hint: None,
    },
];

/// The declared partial order: `(outer, inner)` pairs that may nest,
/// outermost first. Everything not listed (including the reverse of a
/// listed pair and same-class nesting) is a violation.
pub const ORDER: &[(&str, &str)] = &[
    // catalog::store: a shard's journal is appended to while that
    // shard's lock is held — "shard → journal, never the reverse".
    ("shard", "catalog-journal"),
    // obs::Tracer::record: the sink handle is checked (held through
    // the if-let) before the ring shard is taken.
    ("trace-sink", "shard"),
    // cache::ReadCache::invalidate_lfn: the LFN index yields the dead
    // digests, then the pool shards are purged.
    ("cache-lfn-index", "shard"),
    // transfer::WorkPool workers: the queue guard (job fetch) precedes
    // the result-vector push in the same loop body.
    ("pool-queue", "pool-results"),
];

/// Classify a receiver expression (the text left of `.lock()` or the
/// argument of `util::lock(..)`) into a lock class name.
pub fn classify(receiver: &str, path: &str) -> Option<&'static str> {
    for class in CLASSES {
        if let Some(hint) = class.file_hint {
            if !path.contains(hint) {
                continue;
            }
        }
        if class.patterns.iter().any(|p| receiver.contains(p)) {
            return Some(class.name);
        }
    }
    None
}

/// Whether the declared order allows acquiring `inner` while `outer`
/// is held.
pub fn allows(outer: &str, inner: &str) -> bool {
    outer != inner && ORDER.iter().any(|&(o, i)| o == outer && i == inner)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_matches_known_receivers() {
        assert_eq!(classify("self.shards[idx]", "rust/src/catalog/store.rs"), Some("shard"));
        assert_eq!(
            classify("journals[idx]", "rust/src/catalog/store.rs"),
            Some("catalog-journal")
        );
        assert_eq!(classify("self.lfns", "rust/src/cache/mod.rs"), Some("cache-lfn-index"));
        assert_eq!(classify("self.counters", "rust/src/metrics/mod.rs"), Some("metrics-map"));
        assert_eq!(classify("self.sink", "rust/src/obs/mod.rs"), Some("trace-sink"));
        assert_eq!(classify("mystery_mutex", "x.rs"), None);
    }

    #[test]
    fn order_is_directional() {
        assert!(allows("shard", "catalog-journal"));
        assert!(!allows("catalog-journal", "shard"));
        assert!(!allows("shard", "shard"));
        assert!(!allows("shard", "metrics-map"));
    }
}
